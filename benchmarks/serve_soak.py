"""Soak suite: traffic-realistic workloads through the schedulers, audited.

Each row is one :func:`repro.serve.soak.run_soak` over a named workload
preset (arrival process × length tails × tier mix) and a scheduler.
Rows carry the invariant counters the soak harness audits — slot leaks,
lost/duplicate serves, per-row write-position violations — plus the
tail-latency picture (per-window worst TTFT p99/p999, drift vs the
first window) and the seed, so any failure reproduces from the BENCH
file alone (docs/serving.md §Soak testing).

Gating: ``invariants_ok`` (1.0 ⇔ zero violations: the leak counters are
0 in any healthy baseline, so a ratio gate on them would divide by zero
— the boolean is the gateable form) and ``slot_utilization``
(deterministic for a fixed queue).  Wall-clock metrics are recorded for
trajectory plots but not gated — they swing with host load.

``reduced=True`` is the CI-smoke size; the full run streams 20k
requests per row and is the documented local soak
(``python -m repro.launch.soak`` drives bigger ones).
"""

from __future__ import annotations

import jax

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.registry import Suite, register_suite

FULL = {"requests": 20000, "batch_size": 8, "prompt_len": 16, "max_new": 8,
        "window_size": 1024}
REDUCED = {"requests": 256, "batch_size": 4, "prompt_len": 8, "max_new": 6,
           "window_size": 64}
ARCH = "qwen3-0.6b"
SEED = 0
DRIFT_LIMIT = 50.0  # generous: CPU-host TTFT tails are noisy, leaks are not
SPOT_CHECKS = 3

# (workload preset, tier mix, pool quality, scheduler, loop, policy)
CASES = (
    ("steady", (), None, "continuous", "closed", None),
    ("bursty", ((None, 1.0), ("balanced", 3.0)), "balanced", "continuous",
     "closed", None),
    ("flood", (), None, "continuous", "closed", None),
    ("churn", (), None, "continuous", "closed", None),
    ("steady", (), None, "static", "closed", None),
    # open-loop clocked admission: arrival times drive admissibility and
    # the SLO-adaptive policy degrades the pool tier under the bursts
    ("bursty", (), "high", "continuous", "open", "slo-adaptive"),
)
OPEN_SLO_TTFT_S = 0.05
OPEN_STEP_TIME_S = 0.01


def rows(reduced: bool = False) -> list:
    from repro.configs.registry import get_config
    from repro.models.registry import build_model
    from repro.serve.soak import run_soak
    from repro.serve.workload import preset_spec

    sizes = REDUCED if reduced else FULL
    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    out = []
    for workload, tier_mix, quality, scheduler, loop, policy in CASES:
        spec = preset_spec(
            workload, requests=sizes["requests"], prompt_len=sizes["prompt_len"],
            max_new=sizes["max_new"], vocab_size=cfg.vocab_size, tier_mix=tier_mix,
            slo_ttft_s=OPEN_SLO_TTFT_S if loop == "open" else None,
        )
        report = run_soak(
            model, params, spec,
            batch_size=sizes["batch_size"], seed=SEED,
            window_size=sizes["window_size"], scheduler=scheduler,
            quality=quality, drift_limit=DRIFT_LIMIT, spot_check=SPOT_CHECKS,
            loop=loop, policy=policy, step_time_s=OPEN_STEP_TIME_S,
        )
        out.append({"table": "serve_soak", "arch": ARCH,
                    "drift_limit": DRIFT_LIMIT, **report.summary_row()})
    return out


register_suite(Suite(
    name="serve_soak",
    rows=rows,
    description="workload-generator soak: arrival/tier mixes through the "
                "schedulers with slot-accounting + tail-latency audits",
    key_fields=("table", "arch", "workload", "tier_mix", "scheduler",
                "loop", "policy", "requests", "batch_size", "window_size"),
    # slo_attainment is a virtual-clock quantity on the open-loop rows —
    # deterministic for a fixed trace, so it gates exactly; it is absent
    # (non-numeric) on closed-loop rows and skipped there.
    higher_is_better=("invariants_ok", "slot_utilization", "slo_attainment"),
))


if __name__ == "__main__":
    for r in rows(reduced=True):
        print(r)
