"""Roofline table renderer (§Roofline) + the flash-kernel analytic
traffic adjustment (§Perf).

Reads the dry-run JSONL records (results/dryrun_*.jsonl) and reports, per
(arch × shape × mesh): the three roofline terms, the dominant one,
MODEL_FLOPS/HLO_FLOPS, and — for attention-bearing train/prefill cells —
the projected memory term with the Pallas flash-attention kernel
(kernels/flash_attention.py), which keeps the O(S²) score blocks in VMEM.
The projection removes the measured score-block traffic (estimated
analytically from the cell geometry, conservative 5 materializations over
fwd+remat+bwd) and adds the kernel's q/k/v tile reads.
"""

from __future__ import annotations

import json
import os

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.registry import Suite, register_suite
from repro.configs.base import SHAPES
from repro.configs.registry import get_config
from repro.launch.mesh import HW

BASELINE = "results/dryrun_baseline.jsonl"
PERF = "results/dryrun_perf.jsonl"


def load(path):
    if not os.path.exists(path):
        return []
    return [json.loads(l) for l in open(path)]


def attn_score_traffic(cfg, shape, chips: int, accum: int) -> tuple[float, float]:
    """(xla_score_bytes, flash_tile_bytes) per device for a train cell."""
    if not cfg.num_heads:
        return 0.0, 0.0
    s = shape.seq_len
    b = shape.global_batch
    n_dp = 16 if chips == 256 else 32
    b_loc = max(1, b // n_dp)
    h_loc = max(1, cfg.num_heads // 16)
    # which layers attend globally / locally
    pat = cfg.layer_pattern
    attn_frac = sum(k.startswith("attn") for k in pat) / len(pat)
    local_frac = sum(k == "attn_local" for k in pat) / len(pat)
    eff_t = local_frac * min(cfg.local_window, s) + (attn_frac - local_frac) * s
    layers = cfg.num_layers * attn_frac + (cfg.encoder_layers or 0)
    if layers == 0:
        return 0.0, 0.0
    passes = 5.0  # logits+probs materializations over fwd + remat + bwd
    score = b_loc * h_loc * s * eff_t / max(attn_frac, 1e-9) * attn_frac
    xla_bytes = score * 4.0 * passes * layers
    # flash kernel: q,o,do + k/v re-read per q block (bq=512)
    kv_loc = max(1, cfg.num_kv_heads // 16) if cfg.num_kv_heads >= 16 else cfg.num_kv_heads
    nq = max(1, s // 512)
    tile = (3 * b_loc * s * h_loc * cfg.head_dim * 2.0
            + 2 * b_loc * eff_t * kv_loc * cfg.head_dim * 2.0 * nq)
    flash_bytes = tile * 3.0 * layers  # fwd + dq + dkv passes
    return xla_bytes, flash_bytes


def report(recs, *, with_flash=True):
    out = []
    for r in recs:
        if not r.get("ok"):
            out.append({"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                        "ok": False, "error": r.get("error", "")[:100]})
            continue
        row = {
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"], "ok": True,
            "compute_s": r["terms_s"]["compute"],
            "memory_s": r["terms_s"]["memory"],
            "collective_s": r["terms_s"]["collective"],
            "dominant": r["dominant"],
            "bound_s": r["step_time_bound_s"],
            "useful_ratio": r["useful_ratio"],
            "roofline_pct": 100 * r["roofline_fraction"],
            "temp_gb": (r["mem"]["temp_bytes"] or 0) / 1e9,
            "fits_16g_hbm": (r["mem"]["temp_bytes"] or 0) / 1e9 < 16.0,
            "grad_accum": r.get("grad_accum"),
        }
        if with_flash and r["kind"] in ("train", "prefill"):
            cfg = get_config(r["arch"])
            xla_b, flash_b = attn_score_traffic(cfg, SHAPES[r["shape"]], r["chips"],
                                                r.get("grad_accum") or 1)
            if xla_b > 0:
                adj_bytes = max(r["bytes_per_dev"] - xla_b, 0) + flash_b
                mem_s = adj_bytes / HW.HBM_BW
                terms = {"compute": row["compute_s"], "memory": mem_s,
                         "collective": row["collective_s"]}
                row["memory_s_with_flash_kernel"] = mem_s
                row["bound_s_with_flash_kernel"] = max(terms.values())
                mfd = r["model_flops_total"] / r["chips"]
                row["roofline_pct_with_flash_kernel"] = (
                    100 * (mfd / HW.PEAK_FLOPS) / max(max(terms.values()), 1e-30))
        out.append(row)
    return out


def rows(reduced: bool = False):
    # pure post-processing of dry-run records: reduced is identical; empty
    # when no results/dryrun_*.jsonl have been produced in this checkout
    out = []
    for tag, path in (("baseline", BASELINE), ("optimized", PERF)):
        for row in report(load(path)):
            out.append({"table": f"roofline_{tag}", **row})
    return out


register_suite(Suite(
    name="roofline",
    rows=rows,
    description="roofline terms per (arch x shape x mesh) from dry-run JSONL",
    key_fields=("table", "arch", "shape", "mesh"),
    lower_is_better=("bound_s", "memory_s"),
    higher_is_better=("roofline_pct",),
))


if __name__ == "__main__":
    for r in rows():
        print(r)
