"""Self-speculative decoding: bit-match, accept rate, modeled speedup.

For each (draft, verify) quality-tier pair the suite serves one seeded
mixed-length queue twice through the continuous scheduler on a pool
resolved to the *verify* tier — once plain greedy, once under
``SelfSpeculative(k, draft_tier)`` — and reports, per row:

* ``bit_match`` — 1.0 iff every speculative stream equals the plain
  greedy stream token for token.  This is the layer's core contract
  (every committed token is the verify engine's argmax) and the hard
  gate: any KV-rollback bug reads as 0.0 here.  It is only claimed —
  and only gated — on *exact*-verify rows: approximate tiers quantize
  with shape-dependent artifacts, so their ``(B, k+1)`` verify forward
  is a different numerical program than their ``s=1`` decode and
  cross-shape bit-parity is undefined by construction (the same reason
  soak parity spot-checks run only on exact pools).  Approximate-verify
  rows record the informational ``stream_agreement`` fraction instead.
* ``accept_rate`` — accepted / proposed draft tokens.  Greedy decode is
  deterministic for a fixed queue and seed, so this is a deterministic
  quantity (unlike wall time) and gates exactly.
* ``accept_rate_est`` / ``accept_within_bound`` — the error-model lower
  bound from ``engine_config.accept_rate_estimate`` (product over
  budgeted GEMM classes of ``1 - er_draft - er_verify``) and whether
  the measured rate respects it.
* ``speedup_modeled`` — plain ``modeled_cost`` / speculative
  ``modeled_cost``, where each decode round is priced on the virtual
  gate-delay clock (``tier_cycle_factor``: a draft step costs 0.55x an
  exact step, a verify forward one verify-tier step).  Under that cost
  model no registered pair clears break-even — the honest, gated
  finding (docs/serving.md §Self-speculative decoding): speculation
  here buys *verify-tier quality at draft-tier step latency*, not
  throughput, until a cost model with a wider draft/verify gap applies.

All gated metrics are seeded-deterministic; the queue is the same
``synth_requests`` draw for every pair, so rows differ only in tiers.
"""

from __future__ import annotations

import jax
import numpy as np

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.registry import Suite, register_suite

FULL = {"requests": 16, "batch_size": 4, "prompt_len": 16, "gen": 8, "spec_k": 4}
REDUCED = {"requests": 8, "batch_size": 2, "prompt_len": 8, "gen": 6, "spec_k": 3}

ARCHS = ("qwen3-0.6b",)
# (draft, verify): the degenerate pair pins the accept-everything edge,
# the rest span the registered ladder against exact and approximate
# verification.
TIER_PAIRS = (
    ("exact", "exact"),
    ("draft", "exact"),
    ("balanced", "exact"),
    ("draft", "balanced"),
)


def rows(reduced: bool = False) -> list:
    from repro.configs.registry import get_config
    from repro.engine import config as engine_config
    from repro.models.registry import build_model
    from repro.serve import ContinuousScheduler, SelfSpeculative, synth_requests

    cfg_run = REDUCED if reduced else FULL
    out = []
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        queue = synth_requests(
            cfg_run["requests"], prompt_len=cfg_run["prompt_len"],
            gen=cfg_run["gen"], vocab_size=cfg.vocab_size, seed=0,
        )
        for draft, verify in TIER_PAIRS:
            pool_quality = None if verify == "exact" else verify
            plain = ContinuousScheduler(
                model, params, batch_size=cfg_run["batch_size"],
                prompt_len=cfg_run["prompt_len"], max_new=cfg_run["gen"],
                quality=pool_quality,
            ).run(queue)
            spec = ContinuousScheduler(
                model, params, batch_size=cfg_run["batch_size"],
                prompt_len=cfg_run["prompt_len"], max_new=cfg_run["gen"],
                quality=pool_quality,
                strategy=SelfSpeculative(k=cfg_run["spec_k"], draft_tier=draft),
            ).run(queue)
            agreement = np.mean([
                float(np.array_equal(plain.outputs[r.id], spec.outputs[r.id]))
                for r in queue
            ])
            est = engine_config.accept_rate_estimate(draft, verify)
            measured = spec.stats.accept_rate
            best_k, best_gain = engine_config.best_spec_k(draft, verify)
            out.append({
                "table": "speculative",
                "arch": arch,
                "draft_tier": draft,
                "verify_tier": verify,
                "spec_k": cfg_run["spec_k"],
                "batch_size": cfg_run["batch_size"],
                "prompt_len": cfg_run["prompt_len"],
                "gen": cfg_run["gen"],
                "requests": cfg_run["requests"],
                "tokens_out": spec.stats.tokens_out,
                # bit_match is the gated contract on exact verification;
                # on approximate verify tiers cross-shape parity is
                # undefined, so the row carries None (ungated) and the
                # informational agreement fraction instead
                "bit_match": (
                    (1.0 if agreement == 1.0 else 0.0)
                    if verify == "exact" else None
                ),
                "stream_agreement": round(float(agreement), 4),
                "accept_rate": (
                    None if measured is None else round(measured, 4)
                ),
                "accept_rate_est": round(est, 4),
                "accept_within_bound": (
                    1.0 if measured is not None and measured >= est else 0.0
                ),
                "spec_rounds": spec.stats.spec_rounds,
                "spec_proposed": spec.stats.spec_proposed,
                "spec_accepted": spec.stats.spec_accepted,
                "spec_rolled_back": spec.stats.spec_rolled_back,
                "decode_steps_plain": plain.stats.decode_steps,
                "decode_steps_spec": spec.stats.decode_steps,
                "modeled_cost_plain": round(plain.stats.modeled_cost, 4),
                "modeled_cost_spec": round(spec.stats.modeled_cost, 4),
                "speedup_modeled": (
                    round(plain.stats.modeled_cost / spec.stats.modeled_cost, 4)
                    if spec.stats.modeled_cost > 0 else 0.0
                ),
                "best_k_modeled": best_k,
                "best_gain_modeled": round(best_gain, 4),
            })
    return out


register_suite(Suite(
    name="speculative",
    rows=rows,
    description="self-speculative decoding across quality tiers: bit-match "
                "vs plain greedy, accept rate vs the error-model bound, "
                "modeled round-cost speedup",
    key_fields=("table", "arch", "draft_tier", "verify_tier", "spec_k",
                "batch_size", "prompt_len", "gen"),
    # Every gated metric is seeded-deterministic: bit_match and
    # accept_within_bound are the hard 1.0 contracts, accept_rate and
    # speedup_modeled are pure functions of the fixed queue + weights +
    # the virtual gate-delay cost model (no wall clock anywhere).
    higher_is_better=("bit_match", "accept_within_bound", "accept_rate",
                      "speedup_modeled"),
))


if __name__ == "__main__":
    for r in rows(reduced=True):
        print(r)
