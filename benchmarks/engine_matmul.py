"""Engine GEMM latency trajectory: every registered mode × backend × shape.

The run matrix is derived from the engine's own registries
(``engine.list_modes()`` + each mode's Pallas availability), so a newly
registered mode or kernel is benchmarked with no changes here.  Each cell
reports warmed-up wall-time statistics (best-of/median/p95 over
``repeats`` jitted calls; the min is the gated series) — the tracked
counterpart of the paper's latency axis, and the series
``harness --compare`` gates speed PRs against.  Tier rows
(``mode="tier:<name>"``) additionally record ``speedup_vs_exact`` —
the tier-level view of the fused-kernel work (docs/kernels.md).

On CPU the Pallas backend runs in interpret mode (see
``repro.engine.policy``): its absolute numbers are *not* TPU latencies,
but they are comparable run-over-run, which is what the gate needs.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.registry import Suite, register_suite
from repro import engine

N_BITS, T_SPLIT, RANK = 8, 4, 8

FULL = {"shapes": ((128, 256, 128), (256, 256, 256)), "warmup": 2, "repeats": 10}
# The reduced cell must be compute-dominated for the compare gate to mean
# anything: a 16x32x16 jitted call is ~6 us of pure dispatch overhead whose
# median flaps ~2x with host CPU state.  64x128x64 plus best-of-30 timing
# keeps the suite fast while making the gated statistic stable run-over-run.
REDUCED = {"shapes": ((64, 128, 64),), "warmup": 3, "repeats": 30}


def _time_us(fn, *, warmup: int, repeats: int) -> tuple[float, float, float]:
    """(min, median, p95) wall-time in microseconds of ``fn()`` after warmup.

    The min is the gated statistic: at these shapes the median still
    carries host-scheduler and CPU-frequency noise (observed ~2x swings
    run-over-run), while best-of-N converges on the actual cost.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.min(times)), float(np.percentile(times, 50)), float(np.percentile(times, 95))


def _cells():
    """(mode, backend) cells from the engine registries."""
    for mode in engine.list_modes():
        spec = engine.get_mode(mode)
        yield mode, spec, "reference"
        if spec.pallas is not None:
            yield mode, spec, "pallas"


def _tier_cells():
    """(tier, mode, n, t, backend) cells: each registered quality tier's
    mlp-class resolution, run through the fused pallas backend when the
    mode has one — the tier-level view the acceptance gate reads."""
    for tier in engine.list_tiers():
        qc = engine.resolve_tier(tier)
        sel = next((q for q in qc.per_target if q.target == "mlp"), None)
        if sel is None:  # exact tier: approximation disabled
            yield tier, "exact", N_BITS, T_SPLIT, "reference"
            continue
        spec = engine.get_mode(sel.mode)
        backend = "pallas" if spec.pallas is not None else "reference"
        yield tier, sel.mode, sel.n, sel.t, backend


def rows(reduced: bool = False) -> list:
    cfg = REDUCED if reduced else FULL
    key = jax.random.PRNGKey(0)
    out = []
    for m, k, n in cfg["shapes"]:
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)

        def measure(kw):
            if engine.get_mode(kw["mode"]).needs_key:
                kw["key"] = key
            fn = jax.jit(lambda x=x, w=w, kw=kw: engine.matmul(x, w, **kw))
            return _time_us(fn, warmup=cfg["warmup"], repeats=cfg["repeats"])

        exact_min, _, _ = measure(dict(mode="exact", backend="reference"))
        for mode, spec, backend in _cells():
            kw = dict(n=N_BITS, t=T_SPLIT, rank=RANK, mode=mode, backend=backend)
            tmin, median, p95 = measure(kw)
            out.append({
                "table": "engine_matmul",
                "mode": mode,
                "backend": backend,
                "shape": f"{m}x{k}x{n}",
                "M": m, "K": k, "N": n,
                "n": N_BITS, "t": T_SPLIT, "rank": RANK,
                "wall_us_min": round(tmin, 1),
                "wall_us_median": round(median, 1),
                "wall_us_p95": round(p95, 1),
                "warmup": cfg["warmup"],
                "repeats": cfg["repeats"],
            })
        # Tier rows (mode encodes the tier so key_fields stay unchanged
        # and pre-tier baselines don't see them as missing rows).
        for tier, mode, n_bits, t_split, backend in _tier_cells():
            kw = dict(n=n_bits, t=t_split, rank=RANK, mode=mode, backend=backend)
            tmin, median, p95 = measure(kw)
            out.append({
                "table": "engine_matmul",
                "mode": f"tier:{tier}",
                "backend": backend,
                "shape": f"{m}x{k}x{n}",
                "M": m, "K": k, "N": n,
                "n": n_bits, "t": t_split, "rank": RANK,
                "tier_mode": mode,
                "wall_us_min": round(tmin, 1),
                "wall_us_median": round(median, 1),
                "wall_us_p95": round(p95, 1),
                "speedup_vs_exact": round(exact_min / max(tmin, 1e-9), 3),
                "warmup": cfg["warmup"],
                "repeats": cfg["repeats"],
            })
    return out


register_suite(Suite(
    name="engine_matmul",
    rows=rows,
    description="engine mode x backend x shape GEMM wall-times (median/p95)",
    key_fields=("table", "mode", "backend", "shape"),
    # Gate on best-of-N: the median of a tens-of-microseconds jitted call
    # still swings with host CPU state; the min converges (docs/benchmarks.md).
    lower_is_better=("wall_us_min",),
))


if __name__ == "__main__":
    for r in rows(reduced=True):
        print(r)
