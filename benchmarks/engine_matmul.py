"""Engine GEMM latency trajectory: every registered mode × backend × shape.

The run matrix is derived from the engine's own registries
(``engine.list_modes()`` + each mode's Pallas availability), so a newly
registered mode or kernel is benchmarked with no changes here.  Each cell
reports warmed-up wall-time statistics (median + p95 over ``repeats``
jitted calls) — the tracked counterpart of the paper's latency axis, and
the series ``harness --compare`` gates speed PRs against.

On CPU the Pallas backend runs in interpret mode (see
``repro.engine.policy``): its absolute numbers are *not* TPU latencies,
but they are comparable run-over-run, which is what the gate needs.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.registry import Suite, register_suite
from repro import engine

N_BITS, T_SPLIT, RANK = 8, 4, 8

FULL = {"shapes": ((128, 256, 128), (256, 256, 256)), "warmup": 2, "repeats": 10}
REDUCED = {"shapes": ((16, 32, 16),), "warmup": 1, "repeats": 3}


def _time_us(fn, *, warmup: int, repeats: int) -> tuple[float, float]:
    """(median, p95) wall-time in microseconds of ``fn()`` after warmup."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.percentile(times, 50)), float(np.percentile(times, 95))


def _cells():
    """(mode, backend) cells from the engine registries."""
    for mode in engine.list_modes():
        spec = engine.get_mode(mode)
        yield mode, spec, "reference"
        if spec.pallas is not None:
            yield mode, spec, "pallas"


def rows(reduced: bool = False) -> list:
    cfg = REDUCED if reduced else FULL
    key = jax.random.PRNGKey(0)
    out = []
    for m, k, n in cfg["shapes"]:
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        for mode, spec, backend in _cells():
            kw = dict(n=N_BITS, t=T_SPLIT, rank=RANK, mode=mode, backend=backend)
            if spec.needs_key:
                kw["key"] = key
            fn = jax.jit(lambda x=x, w=w, kw=kw: engine.matmul(x, w, **kw))
            median, p95 = _time_us(fn, warmup=cfg["warmup"], repeats=cfg["repeats"])
            out.append({
                "table": "engine_matmul",
                "mode": mode,
                "backend": backend,
                "shape": f"{m}x{k}x{n}",
                "M": m, "K": k, "N": n,
                "n": N_BITS, "t": T_SPLIT, "rank": RANK,
                "wall_us_median": round(median, 1),
                "wall_us_p95": round(p95, 1),
                "warmup": cfg["warmup"],
                "repeats": cfg["repeats"],
            })
    return out


register_suite(Suite(
    name="engine_matmul",
    rows=rows,
    description="engine mode x backend x shape GEMM wall-times (median/p95)",
    key_fields=("table", "mode", "backend", "shape"),
    lower_is_better=("wall_us_median",),
))


if __name__ == "__main__":
    for r in rows(reduced=True):
        print(r)
