"""Paper Figure 2: ER / MAE / MED / NMED / MRED across bit-widths and
splitting points; closed-form Eq. (11) validation; estimator calibration.

Methodology mirrors the paper: exhaustive simulation for small n,
Monte-Carlo with uniform inputs for large n (the paper uses 2^32 samples
for n = 32; the CPU budget here uses 2^20 — statistical error on ER/MED
is < 1% at that size, and the *exhaustive* rows are exact).
"""

from __future__ import annotations

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.registry import Suite, register_suite
from repro.core import error_metrics, error_model

EXHAUSTIVE_N = (4, 6, 8)
MC_N = (12, 16, 32)
MC_SAMPLES = 1 << 20
# CI-smoke subset: exact rows stay exact, one seeded MC row keeps the
# Monte-Carlo path covered.
REDUCED_EXHAUSTIVE_N = (4, 6)
REDUCED_MC_N = (12,)
REDUCED_MC_SAMPLES = 1 << 14


def rows(reduced: bool = False):
    exhaustive_n = REDUCED_EXHAUSTIVE_N if reduced else EXHAUSTIVE_N
    mc_n = REDUCED_MC_N if reduced else MC_N
    mc_samples = REDUCED_MC_SAMPLES if reduced else MC_SAMPLES
    out = []
    for n in exhaustive_n + mc_n:
        ts = sorted({2, n // 4, n // 2} & set(range(1, n)))
        for t in ts:
            if n in exhaustive_n:
                rep = error_metrics.exhaustive_eval(n, t, fix_to_1=False)
            else:
                rep = error_metrics.mc_eval(n, t, samples=mc_samples, fix_to_1=False)
            est = error_model.estimate(n, t, order=1)
            eq11 = error_model.mae_closed_form(n, t)
            out.append({
                "table": "fig2_errors",
                "n": n, "t": t,
                "mode": "exhaustive" if rep.exhaustive else f"mc{mc_samples}",
                "er": rep.er,
                "mae": rep.mae,
                "mae_eq11": eq11,
                "eq11_matches_neg_ed": int(-rep.max_ed_neg == eq11),
                "med_abs": rep.med_abs,
                "nmed": rep.nmed,
                "mred": rep.mred,
                "er_estimator": est.er_msp,
                "p_fix_estimator": est.p_fix,
            })
    return out


register_suite(Suite(
    name="fig2_error_metrics",
    rows=rows,
    description="paper Fig. 2 error metrics (ER/MAE/MED/NMED/MRED) + Eq. 11/estimator",
    key_fields=("table", "n", "t"),
    # deterministic (exhaustive or seeded MC): any error-metric increase is real
    lower_is_better=("er", "mae", "med_abs", "nmed", "mred"),
))


if __name__ == "__main__":
    for r in rows():
        print(r)
