"""Paper Figure 2: ER / MAE / MED / NMED / MRED across bit-widths and
splitting points; closed-form Eq. (11) validation; estimator calibration.

Methodology mirrors the paper: exhaustive simulation for small n,
Monte-Carlo with uniform inputs for large n (the paper uses 2^32 samples
for n = 32; the CPU budget here uses 2^20 — statistical error on ER/MED
is < 1% at that size, and the *exhaustive* rows are exact).
"""

from __future__ import annotations

from repro.core import error_metrics, error_model

EXHAUSTIVE_N = (4, 6, 8)
MC_N = (12, 16, 32)
MC_SAMPLES = 1 << 20


def rows():
    out = []
    for n in EXHAUSTIVE_N + MC_N:
        ts = sorted({2, n // 4, n // 2} & set(range(1, n)))
        for t in ts:
            if n in EXHAUSTIVE_N:
                rep = error_metrics.exhaustive_eval(n, t, fix_to_1=False)
            else:
                rep = error_metrics.mc_eval(n, t, samples=MC_SAMPLES, fix_to_1=False)
            est = error_model.estimate(n, t, order=1)
            eq11 = error_model.mae_closed_form(n, t)
            out.append({
                "n": n, "t": t,
                "mode": "exhaustive" if rep.exhaustive else f"mc{MC_SAMPLES}",
                "er": rep.er,
                "mae": rep.mae,
                "mae_eq11": eq11,
                "eq11_matches_neg_ed": int(-rep.max_ed_neg == eq11),
                "med_abs": rep.med_abs,
                "nmed": rep.nmed,
                "mred": rep.mred,
                "er_estimator": est.er_msp,
                "p_fix_estimator": est.p_fix,
            })
    return out


def main(emit) -> None:
    for r in rows():
        emit("fig2_errors", r)


if __name__ == "__main__":
    for r in rows():
        print(r)
