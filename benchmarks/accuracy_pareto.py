"""Accuracy-configurability sweep: the error-vs-throughput Pareto front.

The paper's headline knob is the splitting point ``t``; this suite drives
it end to end through the accuracy-configuration subsystem
(``repro.engine.config``).  For every candidate split it records

* the controller's closed-form metrics (``sweep_t``: the Eq. 10 ER upper
  estimate ``er_bound``, the deferred-carry NMED estimate, Eq. 11 MAE,
  and the gate-delay cycle cost the controller minimizes),
* the *measured* multiplier error from exhaustive simulation
  (``core.error_metrics``) — every row checks ``er_measured <=
  er_bound``, i.e. the measured error stays within the closed-form bound
  the controller budgets against (``er_within_bound``),
* per engine mode, the measured GEMM wall-time / throughput and the
  GEMM-level relative error against the exact matmul,

and marks the per-mode Pareto-optimal rows (no other split of the same
mode has both lower measured NMED and higher tokens/sec).  A second
table pins every registered quality tier's controller resolution
(tier x target -> (n, t, mode)), so a tier drifting to a different
split shows up as a gated row change.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

from benchmarks.registry import Suite, register_suite
from repro import engine
from repro.core import error_metrics
from repro.engine import config as engine_config

N_BITS = 8  # LUT-backed modes require n <= 8; exhaustive ground truth is cheap

FULL = {
    "ts": (1, 2, 3, 4, 5, 6, 7),
    "modes": ("bitexact", "lowrank", "inject"),
    "shape": (64, 128, 64),
    "warmup": 2,
    "repeats": 8,
}
REDUCED = {
    "ts": (2, 4),
    "modes": ("bitexact",),
    "shape": (16, 32, 16),
    "warmup": 1,
    "repeats": 3,
}


def _time_us(fn, *, warmup: int, repeats: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.percentile(times, 50))


def _mark_pareto(rows: list) -> None:
    """Per mode: a row is Pareto-optimal unless another row of the same
    mode is at least as good on both axes (lower measured NMED, higher
    tokens/sec) and strictly better on one."""
    for row in rows:
        dominated = any(
            other is not row
            and other["mode"] == row["mode"]
            and other["nmed_measured"] <= row["nmed_measured"]
            and other["tokens_per_s"] >= row["tokens_per_s"]
            and (
                other["nmed_measured"] < row["nmed_measured"]
                or other["tokens_per_s"] > row["tokens_per_s"]
            )
            for other in rows
        )
        row["pareto_optimal"] = int(not dominated)


def rows(reduced: bool = False) -> list:
    cfg = REDUCED if reduced else FULL
    m, k, n_cols = cfg["shape"]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n_cols)), jnp.float32)
    exact = np.asarray(x @ w, np.float64)
    exact_norm = float(np.linalg.norm(exact))
    key = jax.random.PRNGKey(0)

    points = {p.t: p for p in engine_config.sweep_t(N_BITS)}
    measured = {
        t: error_metrics.exhaustive_eval(N_BITS, t, fix_to_1=True)
        for t in cfg["ts"]
    }

    out = []
    for mode in cfg["modes"]:
        spec = engine.get_mode(mode)
        for t in cfg["ts"]:
            p, rep = points[t], measured[t]
            kw = dict(n=N_BITS, t=t, mode=mode, backend="reference")
            if spec.needs_key:
                kw["key"] = key
            fn = jax.jit(lambda x=x, w=w, kw=kw: engine.matmul(x, w, **kw))
            wall_us = _time_us(fn, warmup=cfg["warmup"], repeats=cfg["repeats"])
            y = np.asarray(fn(), np.float64)
            out.append({
                "table": "accuracy_pareto",
                "mode": mode,
                "n": N_BITS,
                "t": t,
                # controller side (closed form)
                "er_bound": p.er_bound,
                "nmed_est": p.nmed_est,
                "mae_eq11": p.mae,
                "delay_model": p.delay,
                # measured multiplier error (exhaustive, fix-to-1 on)
                "er_measured": rep.er,
                "nmed_measured": rep.nmed,
                "med_abs_measured": rep.med_abs,
                "er_within_bound": int(rep.er <= p.er_bound),
                # measured GEMM cost / fidelity for this mode
                "gemm_rel_err": float(np.linalg.norm(y - exact) / exact_norm),
                "wall_us_median": round(wall_us, 1),
                "tokens_per_s": round(m / (wall_us * 1e-6), 1),
                "warmup": cfg["warmup"],
                "repeats": cfg["repeats"],
            })
    _mark_pareto(out)

    for tier_name in engine_config.list_tiers():
        qc = engine_config.resolve_tier(tier_name, n=N_BITS)
        for q in qc.per_target:
            out.append({
                "table": "tier_resolution",
                "tier": tier_name,
                "target": q.target,
                "mode": q.mode or qc.mode,
                "n": q.n,
                "t": q.t,
            })

    out.append({
        "table": "accuracy_pareto_summary",
        "rows_within_bound": sum(
            r.get("er_within_bound", 0) for r in out if r["table"] == "accuracy_pareto"
        ),
        "all_rows_within_bound": int(all(
            r["er_within_bound"] for r in out if r["table"] == "accuracy_pareto"
        )),
        "pareto_points": sum(
            r.get("pareto_optimal", 0) for r in out if r["table"] == "accuracy_pareto"
        ),
    })
    return out


register_suite(Suite(
    name="accuracy_pareto",
    rows=rows,
    description="t-sweep per engine mode: measured error vs tokens/sec Pareto "
                "front + controller bounds + tier resolutions",
    key_fields=("table", "mode", "n", "t", "tier", "target"),
    # deterministic metrics only (timing fields are recorded, not gated)
    lower_is_better=("er_measured", "nmed_measured", "gemm_rel_err"),
    higher_is_better=("er_within_bound", "all_rows_within_bound"),
))


if __name__ == "__main__":
    for r in rows(reduced=True):
        print(r)
