"""Serving throughput: the prefill+decode request loop as a tracked metric.

Drives :func:`repro.launch.serve.serve_loop` (the importable request loop
behind ``python -m repro.launch.serve``) on a reduced-family config and
reports tokens/sec, requests/sec, and the per-batch retire latency
distribution — the serving-path counterpart of the paper's latency axis.

The model is always the ``reduced()`` smoke config (full checkpoints are
not servable in this container); ``reduced=True`` additionally shrinks the
request mix to CI-smoke size.  Greedy decoding with a fixed seed, so the
token stream — though not the wall times — is deterministic.
"""

from __future__ import annotations

import jax
import numpy as np

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.registry import Suite, register_suite

FULL = {"requests": 12, "batch_size": 4, "prompt_len": 16, "gen": 8}
REDUCED = {"requests": 4, "batch_size": 2, "prompt_len": 8, "gen": 4}

ARCHS = ("qwen3-0.6b",)
APPROX = (None, "lowrank")  # exact serving + one approximate mode


def rows(reduced: bool = False) -> list:
    from repro.configs.registry import apply_approx, get_config
    from repro.launch.serve import serve_loop
    from repro.models.registry import build_model

    cfg_run = REDUCED if reduced else FULL
    out = []
    for arch in ARCHS:
        for mode in APPROX:
            cfg = get_config(arch).reduced()
            if mode is not None:
                cfg = apply_approx(cfg, mode=mode)
            model = build_model(cfg)
            params = model.init_params(jax.random.PRNGKey(0))
            stats = serve_loop(model, params, seed=0, **cfg_run)
            lats = list(stats.batch_latencies_s)
            out.append({
                "table": "serve_throughput",
                "arch": arch,
                "approx_mode": mode or "none",
                **cfg_run,
                "requests_served": stats.requests,
                "tokens_out": stats.tokens_out,
                "wall_s": round(stats.wall_s, 4),
                "prefill_s": round(stats.prefill_s, 4),
                "decode_s": round(stats.decode_s, 4),
                "tokens_per_s": round(stats.tokens_per_s, 2),
                "requests_per_s": round(stats.requests_per_s, 2),
                "batches": len(lats),
                "batch_retire_s_median": round(float(np.percentile(lats, 50)), 4),
                "batch_retire_s_p95": round(float(np.percentile(lats, 95)), 4),
                "devices": stats.devices,
            })
    return out


register_suite(Suite(
    name="serve_throughput",
    rows=rows,
    description="prefill+decode request-loop tokens/sec and batch-retire latency",
    key_fields=("table", "arch", "approx_mode", "batch_size", "prompt_len", "gen"),
    lower_is_better=("batch_retire_s_median",),
    higher_is_better=("tokens_per_s",),
))


if __name__ == "__main__":
    for r in rows(reduced=True):
        print(r)
