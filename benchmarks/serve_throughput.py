"""Serving throughput: static-batch loop vs continuous-batching scheduler.

Runs the same mixed-length / mixed-budget request queue (one
``repro.serve.synth_requests`` draw per config, so both schedulers see
an identical workload) through both serve loops and reports, per row:

* ``tokens_per_s`` / ``requests_per_s`` — useful (budget/EOS-bounded)
  tokens only, so the two schedulers are directly comparable;
* ``slot_utilization`` — mean fraction of live rows per decode step
  (the static loop's dead decode steps show up here);
* ``ttft_s_p50`` / ``ttft_s_p95`` — time-to-first-token distribution;
* ``request_latency_s_p50`` / ``_p95`` — end-to-end per-request latency;
* ``speedup_vs_static`` (continuous rows) — the retirement win the
  acceptance gate reads.

A second, open-loop section replays one seeded bursty arrival trace
through the continuous scheduler on its deterministic virtual clock,
once under ``StaticTier`` with the pool pinned to the ``high`` tier and
once under ``SLOAdaptive`` (which degrades the pool tier when queue
depth or rolling TTFT breaches the per-request SLO).  Those rows gate
``slo_attainment`` and the adaptive-vs-static acceptance ratios — all
virtual-clock quantities, so they are bit-reproducible for a fixed
trace.

Both loops warm their jitted steps before the timed region (so the
numbers measure scheduling, not compilation) and each scheduler is run
``REPEATS`` times on the same queue with the fastest run kept —
single-run wall times at these scales are dominated by scheduler-
independent host noise.  The model is always the ``reduced()`` smoke
config (full checkpoints are not servable in this container);
``reduced=True`` additionally shrinks the request mix to CI-smoke size.
Greedy decoding with a fixed seed: the token streams — though not the
wall times — are deterministic.
"""

from __future__ import annotations

import jax

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.registry import Suite, register_suite

FULL = {"requests": 16, "batch_size": 4, "prompt_len": 16, "gen": 8}
REDUCED = {"requests": 10, "batch_size": 2, "prompt_len": 8, "gen": 6}
REPEATS = 3

ARCHS = ("qwen3-0.6b",)
APPROX = (None, "lowrank")  # exact serving + one approximate mode

# ---- open-loop clocked section: StaticTier(high) vs SLOAdaptive on the
# same seeded bursty trace.  All gated numbers here (slo attainment,
# queue-delay percentiles, tier switches) are measured on the
# deterministic *virtual* clock, so they are exactly reproducible for a
# fixed trace — unlike the wall-clock metrics above.
OPEN_FULL = {"requests": 64, "batch_size": 4, "prompt_len": 16, "gen": 8}
OPEN_REDUCED = {"requests": 48, "batch_size": 4, "prompt_len": 8, "gen": 6}
OPEN_RATE_RPS = 256.0  # offered burst rate the pool cannot sustain at "high"
OPEN_SLO_TTFT_S = 0.05
OPEN_STEP_TIME_S = 0.01  # virtual seconds per exact decode step


def _p(values, q):
    """Rounded percentile; None (empty distribution) stays None in the row."""
    from repro.serve.stats import percentile

    p = percentile(values, q)
    return None if p is None else round(p, 4)


def _row(arch, mode, cfg_run, result, *, speedup=None) -> dict:
    stats = result.stats
    row = {
        "table": "serve_throughput",
        "arch": arch,
        "approx_mode": mode or "none",
        "scheduler": stats.scheduler,
        "loop": "open" if stats.open_loop else "closed",
        "policy": stats.policy or "none",
        "repeats_best_of": REPEATS,
        **cfg_run,
        "requests_served": stats.requests,
        "tokens_out": stats.tokens_out,
        "wall_s": round(stats.wall_s, 4),
        "prefill_s": round(stats.prefill_s, 4),
        "decode_s": round(stats.decode_s, 4),
        "tokens_per_s": round(stats.tokens_per_s, 2),
        "requests_per_s": round(stats.requests_per_s, 2),
        "decode_steps": stats.decode_steps,
        "slot_utilization": round(stats.slot_utilization, 4),
        "ttft_s_p50": _p(stats.ttft_s, 50),
        "ttft_s_p95": _p(stats.ttft_s, 95),
        "request_latency_s_p50": _p(stats.request_latencies_s, 50),
        "request_latency_s_p95": _p(stats.request_latencies_s, 95),
        "devices": stats.devices,
    }
    if stats.open_loop:
        att = stats.slo_attainment
        row.update({
            "queue_delay_s_p50": _p(stats.queue_delay_s, 50),
            "queue_delay_s_p99": _p(stats.queue_delay_s, 99),
            "slo_attainment": None if att is None else round(att, 4),
            "tier_switches": stats.tier_switches,
            "rejected": stats.rejected,
            "starved": stats.starved,
        })
    if speedup is not None:
        row["speedup_vs_static"] = round(speedup, 3)
    return row


def _open_loop_rows(arch, cfg, model, params, cfg_run) -> list:
    """StaticTier(high) vs SLOAdaptive on one seeded bursty trace.

    Both runs replay the identical arrival-stamped workload draw on the
    deterministic virtual clock against a pool resolved to the ``high``
    tier.  The adaptive row carries the two acceptance ratios the
    baseline gates: ``slo_attainment_vs_static`` (must stay > 1: the
    policy's tier degradation buys strictly more requests inside their
    TTFT SLO) and ``queue_delay_p99_vs_static`` (static p99 / adaptive
    p99, must stay >= 1: the win may not come at the cost of a longer
    queue tail).
    """
    from repro.serve import ContinuousScheduler, SLOAdaptive, StaticTier
    from repro.serve.workload import generate, preset_spec

    spec = preset_spec(
        "bursty", requests=cfg_run["requests"], prompt_len=cfg_run["prompt_len"],
        max_new=cfg_run["gen"], vocab_size=cfg.vocab_size,
        rate_rps=OPEN_RATE_RPS, slo_ttft_s=OPEN_SLO_TTFT_S,
    )
    draw = generate(spec, seed=0)
    out = []
    results = {}
    for policy in (
        StaticTier(),
        SLOAdaptive(slo_ttft_s=OPEN_SLO_TTFT_S, degrade_after=2,
                    recover_after=4, min_dwell_ticks=4),
    ):
        sched = ContinuousScheduler(
            model, params,
            batch_size=cfg_run["batch_size"], prompt_len=cfg_run["prompt_len"],
            max_new=cfg_run["gen"], quality="high",
        )
        results[policy.name] = sched.run(
            list(draw.requests), arrivals_s=list(draw.arrivals_s),
            policy=policy, step_time_s=OPEN_STEP_TIME_S, clock="virtual",
        )
        row = _row(arch, None, cfg_run, results[policy.name])
        row["workload"] = "bursty"
        row["slo_ttft_s"] = OPEN_SLO_TTFT_S
        out.append(row)
    st = results["static"].stats
    ad = results["slo-adaptive"].stats
    st_p99 = _p(st.queue_delay_s, 99)
    ad_p99 = _p(ad.queue_delay_s, 99)
    if st.slo_attainment and ad.slo_attainment is not None:
        out[-1]["slo_attainment_vs_static"] = round(
            ad.slo_attainment / st.slo_attainment, 3)
    if st_p99 and ad_p99:
        out[-1]["queue_delay_p99_vs_static"] = round(st_p99 / ad_p99, 3)
    return out


def rows(reduced: bool = False) -> list:
    from repro.configs.registry import apply_approx, get_config
    from repro.models.registry import build_model
    from repro.serve import ContinuousScheduler, static_serve_loop, synth_requests

    cfg_run = REDUCED if reduced else FULL
    out = []
    for arch in ARCHS:
        for mode in APPROX:
            cfg = get_config(arch).reduced()
            if mode is not None:
                cfg = apply_approx(cfg, mode=mode)
            model = build_model(cfg)
            params = model.init_params(jax.random.PRNGKey(0))
            queue = synth_requests(
                cfg_run["requests"], prompt_len=cfg_run["prompt_len"],
                gen=cfg_run["gen"], vocab_size=cfg.vocab_size, seed=0,
            )
            static = min(
                (static_serve_loop(
                    model, params, queue,
                    batch_size=cfg_run["batch_size"],
                    prompt_len=cfg_run["prompt_len"],
                    gen=cfg_run["gen"], seed=0,
                ) for _ in range(REPEATS)),
                key=lambda r: r.stats.wall_s,
            )
            sched = ContinuousScheduler(
                model, params,
                batch_size=cfg_run["batch_size"], prompt_len=cfg_run["prompt_len"],
                max_new=cfg_run["gen"],
            )
            cont = min(
                (sched.run(queue, warmup=(i == 0)) for i in range(REPEATS)),
                key=lambda r: r.stats.wall_s,
            )
            speedup = (
                cont.stats.tokens_per_s / static.stats.tokens_per_s
                if static.stats.tokens_per_s > 0 else 0.0
            )
            out.append(_row(arch, mode, cfg_run, static))
            out.append(_row(arch, mode, cfg_run, cont, speedup=speedup))
            if mode is None:
                out.extend(_open_loop_rows(
                    arch, cfg, model, params,
                    OPEN_REDUCED if reduced else OPEN_FULL,
                ))
    return out


register_suite(Suite(
    name="serve_throughput",
    rows=rows,
    description="static vs continuous serving: tokens/sec, slot utilization, "
                "TTFT and per-request latency percentiles",
    key_fields=("table", "arch", "approx_mode", "scheduler", "loop", "policy",
                "batch_size", "prompt_len", "gen"),
    # Gate on metrics that survive shared-runner noise: slot_utilization is
    # deterministic for a fixed queue, and speedup_vs_static is a within-run
    # ratio so host-load noise largely cancels.  Absolute tokens_per_s /
    # latency percentiles swing ~2x run-over-run on loaded CPU hosts — they
    # are recorded for trajectory plots but not gated (docs/benchmarks.md).
    # The open-loop metrics are virtual-clock deterministic for a fixed
    # trace, so they gate exactly: slo_attainment per policy row, plus the
    # adaptive row's acceptance ratios (attainment strictly above static,
    # queue p99 no worse).
    higher_is_better=("slot_utilization", "speedup_vs_static",
                      "slo_attainment", "slo_attainment_vs_static",
                      "queue_delay_p99_vs_static"),
))


if __name__ == "__main__":
    for r in rows(reduced=True):
        print(r)
