"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig2       # substring filter

Emits ``table,key=value,...`` CSV-ish lines (one per row) so the output
diffs cleanly across runs.
"""

from __future__ import annotations

import sys
import time

from benchmarks import error_tables, gemm_modes, latency_model, roofline_report

MODULES = [
    ("fig2_error_metrics", error_tables.main),
    ("fig3_latency_area", latency_model.main),
    ("gemm_modes", gemm_modes.main),
    ("roofline", roofline_report.main),
]


def emit(table: str, row: dict) -> None:
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.6g}"
        return str(v)

    print(table + "," + ",".join(f"{k}={fmt(v)}" for k, v in row.items()), flush=True)


def main() -> None:
    pattern = sys.argv[1] if len(sys.argv) > 1 else ""
    failures = 0
    for name, fn in MODULES:
        if pattern and pattern not in name:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            fn(emit)
        except Exception as e:  # noqa: BLE001 — report all benches
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
