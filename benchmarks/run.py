"""CSV-ish benchmark driver — a thin shim over ``benchmarks.harness``.

  PYTHONPATH=src python -m benchmarks.run            # all suites
  PYTHONPATH=src python -m benchmarks.run fig2       # substring filter
  PYTHONPATH=src python -m benchmarks.run --reduced  # CI-smoke shapes

Emits ``table,key=value,...`` lines (one per row) so the output diffs
cleanly across runs.  The suite list is derived from the harness registry
(``benchmarks.registry``) — registering a suite there is the *only* step;
this driver and the JSON-emitting ``benchmarks.harness`` always agree.
For machine-readable ``BENCH_<suite>.json`` artifacts and ``--compare``
regression gating, use ``python -m benchmarks.harness`` instead.
"""

from __future__ import annotations

import sys
import time

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import registry


def modules() -> list:
    """(name, rows_fn) pairs, straight from the suite registry."""
    return [(name, suite.rows) for name, suite in sorted(registry.discover().items())]


def emit(table: str, row: dict) -> None:
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.6g}"
        return str(v)

    print(table + "," + ",".join(f"{k}={fmt(v)}" for k, v in row.items()), flush=True)


def main() -> None:
    argv = sys.argv[1:]
    reduced = "--reduced" in argv
    argv = [a for a in argv if a != "--reduced"]
    pattern = argv[0] if argv else ""
    failures = 0
    for name, rows_fn in modules():
        if pattern and pattern not in name:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            for row in rows_fn(reduced=reduced):
                row = dict(row)
                emit(row.pop("table"), row)
        except Exception as e:  # noqa: BLE001 — report all benches
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
