"""Approximate-GEMM execution modes: wall time (CPU, indicative) and
accuracy vs. the exact GEMM — the framework-level counterpart of the
paper's accuracy-configurability table.

Modes (core.approx_matmul / kernels.ops):
  exact     plain f32 matmul (baseline the paper compares against)
  bitexact  faithful paper semantics via the product LUT
  kernel    the Pallas LUT kernel (interpret mode on CPU)
  lowrank   exact GEMM + rank-r SVD error correction (MXU-friendly)
  inject    moment-matched stochastic error injection (O(1) at scale)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.approx_matmul import approx_matmul
from repro.kernels.ops import approx_matmul_kernel

M, K, N = 128, 256, 128
N_BITS, T_SPLIT = 8, 4
REPEAT = 5


def _timed(fn, *args, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(REPEAT):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return np.asarray(out), (time.perf_counter() - t0) / REPEAT * 1e6


def rows():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    exact = np.asarray(x @ w)
    bitexact = None
    out = []

    runs = [
        ("exact", jax.jit(lambda: approx_matmul(x, w, mode="exact"))),
        ("bitexact", jax.jit(lambda: approx_matmul(x, w, n=N_BITS, t=T_SPLIT, mode="bitexact"))),
        ("kernel_lut", lambda: approx_matmul_kernel(x, w, n=N_BITS, t=T_SPLIT, mode="bitexact")),
        ("lowrank_r8", jax.jit(lambda: approx_matmul(x, w, n=N_BITS, t=T_SPLIT, mode="lowrank", rank=8))),
        ("inject", jax.jit(lambda: approx_matmul(x, w, n=N_BITS, t=T_SPLIT, mode="inject",
                                                 key=jax.random.PRNGKey(0)))),
    ]
    for name, fn in runs:
        got, us = _timed(fn)
        if name == "bitexact":
            bitexact = got
        rel = float(np.abs(got - exact).mean() / np.abs(exact).mean())
        row = {"mode": name, "us_per_call_cpu": round(us, 1),
               "rel_err_vs_exact": rel,
               "shape": f"{M}x{K}x{N}", "n": N_BITS, "t": T_SPLIT}
        if bitexact is not None:
            row["rel_err_vs_bitexact"] = float(
                np.abs(got - bitexact).mean() / np.abs(exact).mean())
        out.append(row)
    return out


def main(emit) -> None:
    for r in rows():
        emit("gemm_modes", r)


if __name__ == "__main__":
    for r in rows():
        print(r)
