"""Approximate-GEMM execution modes: wall time (CPU, indicative) and
accuracy vs. the exact GEMM — the framework-level counterpart of the
paper's accuracy-configurability table.

The run matrix comes straight from the engine's mode registry
(``repro.engine.list_modes()``), on the reference backend plus the Pallas
backend for every mode that registers a kernel body — so a newly
registered mode or backend shows up here with no benchmark changes.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.registry import Suite, register_suite
from repro import engine

M, K, N = 128, 256, 128
REDUCED_MKN = (32, 64, 32)
N_BITS, T_SPLIT = 8, 4
REPEAT = 5


def _timed(fn, *args, repeat=REPEAT, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return np.asarray(out), (time.perf_counter() - t0) / repeat * 1e6


def _runs(x, w):
    """(label, thunk) per registered mode × available backend."""
    key = jax.random.PRNGKey(0)
    for mode in engine.list_modes():
        spec = engine.get_mode(mode)
        kw = dict(n=N_BITS, t=T_SPLIT, mode=mode, rank=8)
        if spec.needs_key:
            kw["key"] = key
        yield mode, jax.jit(lambda kw=kw: engine.matmul(x, w, backend="reference", **kw))
        if spec.pallas is not None:
            yield f"{mode}_pallas", (lambda kw=kw: engine.matmul(x, w, backend="pallas", **kw))


def rows(reduced: bool = False):
    m, k, n = REDUCED_MKN if reduced else (M, K, N)
    repeat = 2 if reduced else REPEAT
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    exact = np.asarray(x @ w)
    bitexact = None
    out = []

    for name, fn in _runs(x, w):
        got, us = _timed(fn, repeat=repeat)
        if name == "bitexact":
            bitexact = got
        rel = float(np.abs(got - exact).mean() / np.abs(exact).mean())
        row = {"table": "gemm_modes", "mode": name, "us_per_call_cpu": round(us, 1),
               "rel_err_vs_exact": rel,
               "shape": f"{m}x{k}x{n}", "n": N_BITS, "t": T_SPLIT}
        if bitexact is not None:
            row["rel_err_vs_bitexact"] = float(
                np.abs(got - bitexact).mean() / np.abs(exact).mean())
        out.append(row)
    return out


register_suite(Suite(
    name="gemm_modes",
    rows=rows,
    description="per-mode GEMM accuracy vs exact + indicative CPU wall time",
    key_fields=("table", "mode", "shape"),
    # accuracy is deterministic per seed; wall time is indicative only, so
    # the gated metrics here are the accuracy columns
    lower_is_better=("rel_err_vs_exact", "rel_err_vs_bitexact"),
))


if __name__ == "__main__":
    for r in rows():
        print(r)
