"""Approximate-GEMM execution modes: wall time (CPU, indicative) and
accuracy vs. the exact GEMM — the framework-level counterpart of the
paper's accuracy-configurability table.

The run matrix comes straight from the engine's mode registry
(``repro.engine.list_modes()``), on the reference backend plus the Pallas
backend for every mode that registers a kernel body — so a newly
registered mode or backend shows up here with no benchmark changes.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine

M, K, N = 128, 256, 128
N_BITS, T_SPLIT = 8, 4
REPEAT = 5


def _timed(fn, *args, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(REPEAT):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return np.asarray(out), (time.perf_counter() - t0) / REPEAT * 1e6


def _runs(x, w):
    """(label, thunk) per registered mode × available backend."""
    key = jax.random.PRNGKey(0)
    for mode in engine.list_modes():
        spec = engine.get_mode(mode)
        kw = dict(n=N_BITS, t=T_SPLIT, mode=mode, rank=8)
        if spec.needs_key:
            kw["key"] = key
        yield mode, jax.jit(lambda kw=kw: engine.matmul(x, w, backend="reference", **kw))
        if spec.pallas is not None:
            yield f"{mode}_pallas", (lambda kw=kw: engine.matmul(x, w, backend="pallas", **kw))


def rows():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    exact = np.asarray(x @ w)
    bitexact = None
    out = []

    for name, fn in _runs(x, w):
        got, us = _timed(fn)
        if name == "bitexact":
            bitexact = got
        rel = float(np.abs(got - exact).mean() / np.abs(exact).mean())
        row = {"mode": name, "us_per_call_cpu": round(us, 1),
               "rel_err_vs_exact": rel,
               "shape": f"{M}x{K}x{N}", "n": N_BITS, "t": T_SPLIT}
        if bitexact is not None:
            row["rel_err_vs_bitexact"] = float(
                np.abs(got - bitexact).mean() / np.abs(exact).mean())
        out.append(row)
    return out


def main(emit) -> None:
    for r in rows():
        emit("gemm_modes", r)


if __name__ == "__main__":
    for r in rows():
        print(r)
