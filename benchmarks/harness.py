"""Structured benchmark harness: registry-driven runner + ``BENCH_*.json``.

The measurement backbone of the repo (docs/benchmarks.md).  Runs any
registered suite (``benchmarks.registry``) and writes one schema-versioned
``BENCH_<suite>.json`` per suite at the repo root: git SHA + environment
fingerprint + the per-row metrics, plus the suite's gating metadata so the
file is self-describing for external diff/plot/gate tooling.

  PYTHONPATH=src python -m benchmarks.harness --list
  PYTHONPATH=src python -m benchmarks.harness --suite engine_matmul --reduced
  PYTHONPATH=src python -m benchmarks.harness --suite all --reduced
  PYTHONPATH=src python -m benchmarks.harness --suite engine_matmul --reduced \
      --compare old/BENCH_engine_matmul.json --threshold 0.25

``--compare`` re-measures, matches rows against the baseline file by the
suite's ``key_fields``, applies the relative ``--threshold`` to every
gated metric, and exits non-zero on any regression — the gate every speed
PR runs against.  ``benchmarks.run`` is a thin CSV-printing shim over the
same registry.
"""

from __future__ import annotations

import argparse
import dataclasses
import datetime
import json
import os
import platform
import subprocess
import sys

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import registry

__all__ = [
    "SCHEMA_VERSION",
    "Regression",
    "env_fingerprint",
    "git_sha",
    "run_suite",
    "bench_path",
    "write_doc",
    "load_doc",
    "validate_doc",
    "compare_docs",
    "main",
]

SCHEMA_VERSION = 1
DEFAULT_THRESHOLD = 0.25  # 25% relative tolerance on gated metrics

_TOP_KEYS = {
    "schema_version": int,
    "suite": str,
    "reduced": bool,
    "git_sha": str,
    "created_at": str,
    "env": dict,
    "gating": dict,
    "row_count": int,
    "rows": list,
}
_ENV_KEYS = ("python", "jax", "numpy", "jax_backend", "device_count", "platform")
_GATING_KEYS = ("key_fields", "lower_is_better", "higher_is_better")


def env_fingerprint() -> dict:
    """The environment facts that make two BENCH files comparable."""
    import jax
    import numpy as np

    return {
        "python": platform.python_version(),
        "jax": jax.__version__,
        "numpy": np.__version__,
        "jax_backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "force_interpret": os.environ.get("REPRO_FORCE_INTERPRET", ""),
    }


def git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True,
            text=True,
            timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def run_suite(suite: registry.Suite, *, reduced: bool = False) -> dict:
    """Execute one suite and assemble its BENCH document."""
    rows = suite.rows(reduced=reduced)
    return {
        "schema_version": SCHEMA_VERSION,
        "suite": suite.name,
        "reduced": reduced,
        "git_sha": git_sha(),
        "created_at": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "env": env_fingerprint(),
        "gating": suite.gating(),
        "row_count": len(rows),
        "rows": rows,
    }


def bench_path(suite_name: str, out_dir: str = ".") -> str:
    return os.path.join(out_dir, f"BENCH_{suite_name}.json")


def validate_doc(doc: dict) -> None:
    """Raise ``ValueError`` unless ``doc`` is a well-formed BENCH document."""
    if not isinstance(doc, dict):
        raise ValueError(f"BENCH document must be an object, got {type(doc).__name__}")
    for key, typ in _TOP_KEYS.items():
        if key not in doc:
            raise ValueError(f"BENCH document missing key {key!r}")
        if not isinstance(doc[key], typ):
            raise ValueError(
                f"BENCH key {key!r} must be {typ.__name__}, got {type(doc[key]).__name__}"
            )
    if doc["schema_version"] != SCHEMA_VERSION:
        raise ValueError(
            f"unsupported schema_version {doc['schema_version']} (harness speaks {SCHEMA_VERSION})"
        )
    for key in _ENV_KEYS:
        if key not in doc["env"]:
            raise ValueError(f"BENCH env fingerprint missing {key!r}")
    for key in _GATING_KEYS:
        if not isinstance(doc["gating"].get(key), list):
            raise ValueError(f"BENCH gating metadata missing list {key!r}")
    if doc["row_count"] != len(doc["rows"]):
        raise ValueError("BENCH row_count disagrees with len(rows)")
    for i, row in enumerate(doc["rows"]):
        if not isinstance(row, dict) or "table" not in row:
            raise ValueError(f"BENCH row {i} must be an object with a 'table' key")


def write_doc(doc: dict, out_dir: str = ".") -> str:
    validate_doc(doc)
    path = bench_path(doc["suite"], out_dir)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True, default=float)
        f.write("\n")
    return path


def load_doc(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    validate_doc(doc)
    return doc


@dataclasses.dataclass(frozen=True)
class Regression:
    suite: str
    key: tuple
    metric: str
    direction: str  # "lower_is_better" | "higher_is_better"
    baseline: float
    current: float
    rel_change: float  # positive == worse, in the gated direction

    def __str__(self) -> str:
        return (
            f"{self.suite} {dict(zip(self.key[::2], self.key[1::2]))} "
            f"{self.metric}: {self.baseline:.6g} -> {self.current:.6g} "
            f"({100 * self.rel_change:+.1f}% worse, {self.direction})"
        )


def _row_key(row: dict, key_fields) -> tuple:
    out = []
    for k in key_fields:
        out.append(k)
        out.append(str(row.get(k)))
    return tuple(out)


def compare_docs(
    current: dict, baseline: dict, *, threshold: float = DEFAULT_THRESHOLD
) -> list[Regression]:
    """Gated metric comparison; returns the (possibly empty) regression list.

    Rows are matched by the *current* document's ``key_fields``; rows
    absent from the baseline (new modes, new shapes) are not regressions,
    but baseline rows that *disappear* from the current run are — a
    vanished series (e.g. a mode that silently stopped registering its
    Pallas body) must not read as "no regressions".  A gated metric
    regresses when it moves in the bad direction by more than
    ``threshold`` relative to the baseline value.
    """
    validate_doc(current)
    validate_doc(baseline)
    if current["suite"] != baseline["suite"]:
        raise ValueError(
            f"cannot compare suite {current['suite']!r} against {baseline['suite']!r}"
        )
    if current["reduced"] != baseline["reduced"]:
        raise ValueError(
            "cannot compare a reduced run against a full baseline (or vice versa)"
        )
    gating = current["gating"]
    key_fields = gating["key_fields"]
    base_rows = {_row_key(r, key_fields): r for r in baseline["rows"]}
    regressions: list[Regression] = []
    for row in current["rows"]:
        key = _row_key(row, key_fields)
        base = base_rows.get(key)
        if base is None:
            continue
        for direction, metrics in (
            ("lower_is_better", gating["lower_is_better"]),
            ("higher_is_better", gating["higher_is_better"]),
        ):
            for metric in metrics:
                cur_v, base_v = row.get(metric), base.get(metric)
                if not isinstance(cur_v, (int, float)) or not isinstance(base_v, (int, float)):
                    continue
                if base_v == 0:
                    continue  # no relative scale to gate against
                if direction == "lower_is_better":
                    rel = (cur_v - base_v) / abs(base_v)
                else:
                    rel = (base_v - cur_v) / abs(base_v)
                if rel > threshold:
                    regressions.append(
                        Regression(
                            suite=current["suite"],
                            key=key,
                            metric=metric,
                            direction=direction,
                            baseline=float(base_v),
                            current=float(cur_v),
                            rel_change=float(rel),
                        )
                    )
    current_keys = {_row_key(r, key_fields) for r in current["rows"]}
    for key in base_rows:
        if key not in current_keys:
            regressions.append(
                Regression(
                    suite=current["suite"],
                    key=key,
                    metric="row_present",
                    direction="missing_row",
                    baseline=1.0,
                    current=0.0,
                    rel_change=1.0,
                )
            )
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.harness", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--suite", default=None,
                    help="suite name, or 'all' (see --list)")
    ap.add_argument("--reduced", action="store_true",
                    help="CI-smoke shapes/samples (same schema)")
    ap.add_argument("--list", action="store_true", help="list registered suites")
    ap.add_argument("--out-dir", default=".",
                    help="where BENCH_<suite>.json lands (default: cwd)")
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="gate this run against a baseline BENCH file; "
                         "exits 1 on regression")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help=f"relative regression tolerance (default {DEFAULT_THRESHOLD})")
    args = ap.parse_args(argv)

    suites = registry.discover()
    if args.list or args.suite is None:
        for name in sorted(suites):
            print(f"{name:20s} {suites[name].description}")
        return 0

    if args.suite == "all":
        selected = [suites[n] for n in sorted(suites)]
    else:
        selected = [registry.get_suite(args.suite)]
    if args.compare is not None and len(selected) != 1:
        print("--compare needs exactly one --suite", file=sys.stderr)
        return 2

    failures = 0
    regressions: list[Regression] = []
    for suite in selected:
        print(f"# === {suite.name} ===", flush=True)
        try:
            doc = run_suite(suite, reduced=args.reduced)
            path = write_doc(doc, args.out_dir)
        except Exception as e:  # noqa: BLE001 — report, keep benching
            failures += 1
            print(f"# {suite.name} FAILED: {type(e).__name__}: {e}", flush=True)
            continue
        print(f"# wrote {path} ({doc['row_count']} rows)", flush=True)
        if args.compare is not None:
            try:
                baseline = load_doc(args.compare)
                regressions = compare_docs(doc, baseline, threshold=args.threshold)
            except (OSError, ValueError) as e:
                failures += 1
                print(f"# compare vs {args.compare} FAILED: "
                      f"{type(e).__name__}: {e}", flush=True)
                continue
            for r in regressions:
                print(f"REGRESSION: {r}", flush=True)
            if not regressions:
                print(f"# no regressions vs {args.compare} "
                      f"(threshold {args.threshold:.0%})", flush=True)
    return 1 if (failures or regressions) else 0


if __name__ == "__main__":
    sys.exit(main())
