"""Benchmark-suite registry: the one list of what can be measured.

Every module under ``benchmarks/`` that defines a suite registers it here
at import time (mirroring ``repro.engine``'s mode registry), and
:func:`discover` imports the whole package so the set of suites is derived
from the filesystem — ``benchmarks.run`` and ``benchmarks.harness`` both
iterate this registry, so a new suite cannot exist in one driver but not
the other.

A :class:`Suite` carries, besides its row producer, the *gating metadata*
consumed by ``harness --compare``: which row fields identify a row across
runs (``key_fields``) and which metrics regress by going up
(``lower_is_better``) or down (``higher_is_better``).  The metadata is
embedded verbatim in the emitted ``BENCH_<suite>.json`` so external tools
can gate without importing this package.
"""

from __future__ import annotations

import dataclasses
import importlib
import pkgutil
from typing import Callable

__all__ = ["Suite", "register_suite", "get_suite", "list_suites", "discover"]

# package-infrastructure modules that do not define suites
_SKIP = {"harness", "registry", "run"}


@dataclasses.dataclass(frozen=True)
class Suite:
    """One registered benchmark suite.

    ``rows(reduced=False)`` returns a list of flat dicts; every row must
    carry a ``"table"`` key (one suite may emit several paper tables, e.g.
    ``fig3_latency_area`` + ``fig3_summary``).  ``reduced=True`` shrinks
    shapes/samples to CI-smoke size without changing the schema.
    """

    name: str
    rows: Callable[..., list]
    description: str = ""
    key_fields: tuple = ("table",)
    lower_is_better: tuple = ()
    higher_is_better: tuple = ()

    def gating(self) -> dict:
        return {
            "key_fields": list(self.key_fields),
            "lower_is_better": list(self.lower_is_better),
            "higher_is_better": list(self.higher_is_better),
        }


_REGISTRY: dict[str, Suite] = {}


def register_suite(suite: Suite) -> Suite:
    if suite.name in _REGISTRY:
        raise ValueError(f"suite {suite.name!r} is already registered")
    _REGISTRY[suite.name] = suite
    return suite


def get_suite(name: str) -> Suite:
    discover()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown suite {name!r}; registered suites: {list_suites()}"
        ) from None


def list_suites() -> list[str]:
    discover()
    return sorted(_REGISTRY)


def discover() -> dict[str, Suite]:
    """Import every suite module in the package; return the registry."""
    import benchmarks  # namespace package — resolves from PYTHONPATH/cwd

    for info in pkgutil.iter_modules(benchmarks.__path__):
        if info.name in _SKIP or info.name.startswith("_"):
            continue
        importlib.import_module(f"benchmarks.{info.name}")
    return dict(_REGISTRY)
