"""Paper Figure 3: latency / area / power trade-offs of the accurate vs.
approximate (segmented) sequential multiplier.

We cannot tape out from this container, so the trade-off is reproduced
with standard gate-delay models over the same design space the paper
sweeps (n in {4..256}, t = n/2):

  ripple-carry:     delay(n) = n * t_fa                (the paper's LUT
                    carry chains on the Zynq fabric behave linearly)
  segmented:        delay(n, t) = max(t, n - t) * t_fa + t_mux
  carry-lookahead:  delay(n) = (4 + 2*ceil(log4 n)) * t_g  (ASIC flavour)

Reported: latency reduction % (paper: FPGA avg 19.15%, up to 29%;
ASIC avg 16.1%, up to 34.14%), area proxy (adder full-adder cells +
fix-to-1 mux cells, paper: <3% overhead), and the sequential-vs-
combinatorial area ratio (paper: up to 99% savings at n=256).
"""

from __future__ import annotations

import math

if __package__ in (None, ""):  # direct script run: python benchmarks/<mod>.py
    import os
    import sys

    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

from benchmarks.registry import Suite, register_suite

# The gate-delay cost model lives with the accuracy-configuration
# controller (repro.engine.config) — the (n, t) resolver minimizes the
# same per-cycle critical path this suite plots, so the two cannot drift.
from repro.engine.config import T_FA, T_MUX, ripple_delay, segmented_delay

NS = (4, 8, 16, 32, 64, 128, 256)


def cla_delay(n: int) -> float:
    return (4 + 2 * math.ceil(math.log(max(n, 4), 4))) * 1.0


def area_cells(n: int, segmented: bool) -> float:
    # n FA cells + registers; segmented adds the n+t fix-to-1 muxes + D-FF
    base = n * 8 + 2 * n * 4  # FA + two shift registers (paper Fig. 1)
    if segmented:
        base += (n + n // 2) * 1 + 2  # mux cells + carry D-FF
    return base


def combinatorial_area(n: int) -> float:
    return (n - 1) * (n * 8)  # n-1 adders of n bits (paper Section III)


def rows(reduced: bool = False):
    # closed-form gate models: already instantaneous, reduced is identical
    out = []
    for n in NS:
        t = n // 2
        acc = ripple_delay(n)
        app = segmented_delay(n, t)
        out.append({
            "table": "fig3_latency_area",
            "n": n, "t": t,
            "latency_accurate": acc,
            "latency_approx": app,
            "latency_reduction_pct": 100 * (1 - app / acc),
            "area_accurate": area_cells(n, False),
            "area_approx": area_cells(n, True),
            "area_overhead_pct": 100 * (area_cells(n, True) / area_cells(n, False) - 1),
            "seq_vs_comb_area_savings_pct": 100 * (1 - area_cells(n, True) / combinatorial_area(n)) if n > 2 else 0.0,
        })
    return out


def summary(rs):
    red = [r["latency_reduction_pct"] for r in rs]
    return {
        "table": "fig3_summary",
        "avg_latency_reduction_pct": sum(red) / len(red),
        "max_latency_reduction_pct": max(red),
        "max_area_overhead_pct": max(r["area_overhead_pct"] for r in rs),
        "paper_fpga_avg_pct": 19.15,
        "paper_fpga_max_pct": 29.0,
        "paper_asic_avg_pct": 16.1,
        "paper_asic_max_pct": 34.14,
    }


def suite_rows(reduced: bool = False):
    rs = rows(reduced)
    return rs + [summary(rs)]


register_suite(Suite(
    name="fig3_latency_area",
    rows=suite_rows,
    description="paper Fig. 3 latency/area trade-off (gate-delay models)",
    key_fields=("table", "n", "t"),
    lower_is_better=("latency_approx", "area_overhead_pct"),
    higher_is_better=("latency_reduction_pct", "avg_latency_reduction_pct"),
))


if __name__ == "__main__":
    for r in suite_rows():
        print(r)
