"""Core multiplier: word-packed implementation vs. the paper's literal
boolean recurrences, exactness, closed-form MAE (Eq. 11)."""

import numpy as np
import pytest

from repro.core import boolean_ref, error_model, seqmul


def _all_pairs(n):
    v = np.arange(1 << n, dtype=np.uint64)
    return np.repeat(v, 1 << n), np.tile(v, 1 << n)


@pytest.mark.parametrize("n", [2, 3, 4, 5, 6])
def test_exact_matches_product_exhaustive(n):
    a, b = _all_pairs(n)
    w = seqmul.seq_mul_words(a.astype(np.uint32), b.astype(np.uint32),
                             n=n, t=max(1, n // 2), approx=False)
    got = seqmul.assemble_product_u64(w, n=n, t=max(1, n // 2))
    np.testing.assert_array_equal(got, a * b)


@pytest.mark.parametrize("n", [4, 6, 8])
@pytest.mark.parametrize("fix", [True, False])
def test_approx_matches_boolean_reference_exhaustive(n, fix):
    a, b = _all_pairs(n)
    for t in range(1, n):
        w = seqmul.seq_mul_words(a.astype(np.uint32), b.astype(np.uint32),
                                 n=n, t=t, approx=True, fix_to_1=fix)
        got = seqmul.assemble_product_u64(w, n=n, t=t)
        ref_bits = boolean_ref.mul_approx_bits(
            boolean_ref.bits_from_int(a, n), boolean_ref.bits_from_int(b, n),
            t=t, fix_to_1=fix)
        ref = boolean_ref.int_from_bits(ref_bits)
        np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("n", [12, 16, 24, 31, 32])
def test_large_n_random_vs_boolean_reference(n):
    rng = np.random.default_rng(n)
    a = rng.integers(0, 1 << n, size=512, dtype=np.uint64)
    b = rng.integers(0, 1 << n, size=512, dtype=np.uint64)
    t = n // 2
    for fix in (True, False):
        w = seqmul.seq_mul_words(a.astype(np.uint32), b.astype(np.uint32),
                                 n=n, t=t, approx=True, fix_to_1=fix)
        got = seqmul.assemble_product_u64(w, n=n, t=t)
        ref = boolean_ref.int_from_bits(boolean_ref.mul_approx_bits(
            boolean_ref.bits_from_int(a, n), boolean_ref.bits_from_int(b, n),
            t=t, fix_to_1=fix))
        np.testing.assert_array_equal(got, ref)


def test_exact_boolean_reference_itself():
    n = 5
    a, b = _all_pairs(n)
    bits = boolean_ref.mul_exact_bits(
        boolean_ref.bits_from_int(a, n), boolean_ref.bits_from_int(b, n))
    np.testing.assert_array_equal(boolean_ref.int_from_bits(bits), a * b)


@pytest.mark.parametrize("n,t", [(4, 2), (6, 2), (6, 3), (8, 4), (8, 2)])
def test_mae_closed_form_eq11(n, t):
    """Eq. (11): max |ED| == 2^{n+t-1} - 2^{t+1} (fix-to-1 disabled;
    see error_model docstring for the sign-structure note)."""
    a, b = _all_pairs(n)
    w = seqmul.seq_mul_words(a.astype(np.uint32), b.astype(np.uint32),
                             n=n, t=t, approx=True, fix_to_1=False)
    approx = seqmul.assemble_product_u64(w, n=n, t=t).astype(np.int64)
    ed = (a * b).astype(np.int64) - approx
    # negative side (deferred carries overshoot): exactly Eq. 11
    assert -int(ed.min()) == error_model.mae_closed_form(n, t)
    # positive side (final carry dropped): bounded by 2^{n+t-1}
    assert int(ed.max()) <= error_model.max_ed_dropped_carry(n, t)


@pytest.mark.parametrize("n,t", [(4, 2), (6, 3), (8, 4)])
def test_fix_to_1_reduces_worst_case(n, t):
    a, b = _all_pairs(n)
    eds = {}
    for fix in (False, True):
        w = seqmul.seq_mul_words(a.astype(np.uint32), b.astype(np.uint32),
                                 n=n, t=t, approx=True, fix_to_1=fix)
        approx = seqmul.assemble_product_u64(w, n=n, t=t).astype(np.int64)
        eds[fix] = (a * b).astype(np.int64) - approx
    # fix-to-1 strictly shrinks the positive worst case ...
    assert eds[True].max() < eds[False].max()
    # ... and only changes results where it fires (c_last == 1)
    w = seqmul.seq_mul_words(a.astype(np.uint32), b.astype(np.uint32),
                             n=n, t=t, approx=True, fix_to_1=False)
    fired = np.asarray(w.c_last).astype(bool)
    np.testing.assert_array_equal(eds[True][~fired], eds[False][~fired])


def test_approx_errors_only_when_carry_crosses():
    """Products whose exact computation never generates a carry at the
    split are bit-exact under the approximate multiplier."""
    n, t = 8, 4
    a, b = _all_pairs(n)
    w = seqmul.seq_mul_words(a.astype(np.uint32), b.astype(np.uint32),
                             n=n, t=t, approx=True, fix_to_1=True)
    approx = seqmul.assemble_product_u64(w, n=n, t=t)
    exact = a * b
    # small operands never produce carries across bit t-1
    small = (a < (1 << (t // 2))) & (b < (1 << (t // 2)))
    np.testing.assert_array_equal(approx[small], exact[small])


def test_validation_errors():
    a = np.zeros(4, np.uint32)
    with pytest.raises(ValueError):
        seqmul.seq_mul_words(a, a, n=0, t=1, approx=True)
    with pytest.raises(ValueError):
        seqmul.seq_mul_words(a, a, n=8, t=8, approx=True)
    with pytest.raises(ValueError):
        seqmul.seq_mul_words(a, a, n=33, t=4, approx=True)


def test_n1_degenerate_split():
    """n=1 is advertised (1 <= n <= MAX_N) and must not be rejected: the
    split is degenerate (no MSP to segment), t=1 is accepted, and exact
    == approx == a*b over the whole 1-bit operand space."""
    from repro.engine.recurrence import validate_nt

    validate_nt(1, 1)  # the degenerate split is legal...
    with pytest.raises(ValueError, match="degenerate"):
        validate_nt(1, 2)  # ...but only t=1
    a, b = _all_pairs(1)
    a, b = a.astype(np.uint32), b.astype(np.uint32)
    np.testing.assert_array_equal(
        np.asarray(seqmul.seq_mul_exact_u32(a, b, n=1)), a * b
    )
    for fix in (False, True):
        np.testing.assert_array_equal(
            np.asarray(seqmul.seq_mul_approx_u32(a, b, n=1, t=1, fix_to_1=fix)), a * b
        )
    w = seqmul.seq_mul_words(a, b, n=1, t=1, approx=True)
    np.testing.assert_array_equal(seqmul.assemble_product_u64(w, n=1, t=1), a * b)
    np.testing.assert_array_equal(np.asarray(w.c_last), np.zeros_like(a))


def test_packed_u32_helpers():
    n, t = 8, 4
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1 << n, size=256, dtype=np.uint32)
    b = rng.integers(0, 1 << n, size=256, dtype=np.uint32)
    exact = seqmul.seq_mul_exact_u32(a, b, n=n)
    np.testing.assert_array_equal(np.asarray(exact), a * b)
    approx = np.asarray(seqmul.seq_mul_approx_u32(a, b, n=n, t=t))
    w = seqmul.seq_mul_words(a, b, n=n, t=t, approx=True, fix_to_1=True)
    np.testing.assert_array_equal(approx, seqmul.assemble_product_u64(w, n=n, t=t))
