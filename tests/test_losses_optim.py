"""Vocab-chunked CE vs dense reference; AdamW (8-bit states); gradient
compression error-feedback invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.optim import adamw, compress
from repro.train.losses import chunked_cross_entropy, cross_entropy_dense


@pytest.mark.parametrize("v,chunk", [(100, 32), (256, 256), (1000, 128), (64, 64)])
@pytest.mark.parametrize("softcap", [None, 30.0])
def test_chunked_ce_matches_dense(v, chunk, softcap):
    rng = np.random.default_rng(v)
    b, s, d = 2, 8, 16
    hidden = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, v)) * 0.5, jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    got = chunked_cross_entropy(hidden, w, labels, softcap=softcap, v_chunk=chunk)
    want = cross_entropy_dense(jnp.einsum("bsd,dv->bsv", hidden, w), labels, softcap=softcap)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_chunked_ce_gradients_match_dense():
    rng = np.random.default_rng(0)
    b, s, d, v = 2, 4, 8, 100
    hidden = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, v)) * 0.5, jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)

    g1 = jax.grad(lambda h, w_: chunked_cross_entropy(h, w_, labels, v_chunk=32),
                  argnums=(0, 1))(hidden, w)
    g2 = jax.grad(
        lambda h, w_: cross_entropy_dense(jnp.einsum("bsd,dv->bsv", h, w_), labels),
        argnums=(0, 1))(hidden, w)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-6)


def _quad_problem(seed=0, dim=64):
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.standard_normal(dim), jnp.float32)
    params = {"w": jnp.zeros((dim,), jnp.float32), "scale": jnp.zeros((), jnp.float32)}

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2) + (p["scale"] - 1.0) ** 2

    return params, loss_fn


@pytest.mark.parametrize("bits", [32, 8])
def test_adamw_converges_quadratic(bits):
    params, loss_fn = _quad_problem()
    tcfg = TrainConfig(learning_rate=0.05, warmup_steps=5, total_steps=200,
                       weight_decay=0.0, opt_state_bits=bits)
    opt = adamw.init(params, tcfg)
    for _ in range(200):
        grads = jax.grad(loss_fn)(params)
        params, opt, metrics = adamw.update(grads, opt, params, tcfg)
    assert float(loss_fn(params)) < 0.05
    assert float(metrics["lr"]) > 0


def test_adamw_8bit_tracks_fp32():
    params, loss_fn = _quad_problem(seed=1)
    initial = float(loss_fn(params))
    runs = {}
    for bits in (32, 8):
        p = jax.tree_util.tree_map(jnp.copy, params)
        tcfg = TrainConfig(learning_rate=0.05, warmup_steps=5, total_steps=50,
                           weight_decay=0.0, opt_state_bits=bits)
        opt = adamw.init(p, tcfg)
        for _ in range(50):
            grads = jax.grad(loss_fn)(p)
            p, opt, _ = adamw.update(grads, opt, p, tcfg)
        runs[bits] = float(loss_fn(p))
    # block-quantized moments add noise on a 50-step probe; the contract
    # is qualitative tracking: both runs make major progress and the
    # 8-bit run stays within a small factor of fp32
    assert runs[32] < 0.2 * initial
    assert runs[8] < 0.2 * initial
    assert runs[8] < runs[32] * 3 + 0.5


def test_no_weight_decay_on_vectors():
    """Norm scales (ndim < 2) must not be decayed."""
    params = {"w": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=10,
                       weight_decay=1.0)
    opt = adamw.init(params, tcfg)
    grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    new_p, _, _ = adamw.update(grads, opt, params, tcfg)
    assert float(jnp.abs(new_p["scale"] - 1.0).max()) < 1e-6  # untouched
    assert float(jnp.abs(new_p["w"] - 1.0).max()) > 1e-4  # decayed


def test_compress_error_feedback_invariant():
    """deq + residual' == grad + residual (lossless bookkeeping)."""
    rng = np.random.default_rng(2)
    grads = {"a": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)}
    state = compress.init_state(grads)
    deq, new_state, _ = compress.compress_grads(grads, state)
    lhs = np.asarray(deq["a"]) + np.asarray(new_state.residual["a"])
    rhs = np.asarray(grads["a"])
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-6)


def test_compressed_training_converges():
    params, loss_fn = _quad_problem(seed=3)
    tcfg = TrainConfig(learning_rate=0.05, warmup_steps=5, total_steps=200,
                       weight_decay=0.0)
    opt = adamw.init(params, tcfg)
    cstate = compress.init_state(params)
    for _ in range(200):
        grads = jax.grad(loss_fn)(params)
        grads, cstate, _ = compress.compress_grads(grads, cstate)
        params, opt, _ = adamw.update(grads, opt, params, tcfg)
    assert float(loss_fn(params)) < 0.05
