"""Prefill + decode (KV / recurrent caches) must reproduce the
teacher-forced full forward — the strongest cache-correctness check."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.registry import build_model
from repro.train.steps import make_decode_step, make_prefill_step

# covers: GQA global, local+softcap+postnorm, MQA+RG-LRU hybrid, SSD,
# MoE, M-RoPE, enc-dec cross-attention
ARCHS = [
    "qwen3-0.6b", "gemma2-9b", "recurrentgemma-2b", "mamba2-130m",
    "granite-moe-1b-a400m", "qwen2-vl-7b", "seamless-m4t-large-v2",
]
B, PROMPT, GEN = 2, 8, 6


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.num_experts:
        # capacity-based MoE dropping is batch-dependent by design; lift the
        # capacity so prefill-vs-full-forward parity is well-defined
        cfg = cfg.reduced(capacity_factor=8.0)
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    total = PROMPT + GEN
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, total)), jnp.int32)

    # ---- reference: full forward over the whole sequence
    ctx = m.ctx()
    pos = jnp.arange(total, dtype=jnp.int32)[None].repeat(B, 0)
    if cfg.use_mrope:
        pos = jnp.broadcast_to(pos[None], (3, B, total))
    kw = {}
    src = None
    if cfg.is_encdec:
        src = jnp.asarray(rng.standard_normal((B, PROMPT, cfg.d_model)), jnp.float32)
        kw["src_embeds"] = src
        kw["src_pos"] = jnp.arange(PROMPT, dtype=jnp.int32)[None].repeat(B, 0)
    hidden_full, _, _ = m.forward(params, toks, pos, ctx, **kw)
    logits_full = np.asarray(m.lm_head(params, hidden_full), np.float32)

    # ---- prefill PROMPT tokens, then decode the rest teacher-forced
    batch = {"tokens": toks[:, :PROMPT]}
    if cfg.is_encdec:
        batch["src_embeds"] = src
        batch["src_pos"] = kw["src_pos"]
    prefill = make_prefill_step(m, total, mem_len=PROMPT if cfg.is_encdec else 0)
    decode = make_decode_step(m)
    caches, logits_p = prefill(params, batch)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1], np.float32), logits_full[:, PROMPT - 1],
        rtol=2e-2, atol=2e-2,
    )
    for g in range(GEN):
        tok = toks[:, PROMPT + g][:, None]
        logits_d, caches = decode(params, caches, tok, jnp.int32(PROMPT + g))
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32), logits_full[:, PROMPT + g],
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode step {g} diverged from full forward",
        )
