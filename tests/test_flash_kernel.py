"""Pallas flash-attention kernel vs. the models.attention oracle —
forward and gradients, sweeping causal/window/softcap/GQA (interpret)."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import _attend_direct


def _inputs(b=2, s=64, t=64, h=4, kv=2, hd=32, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, kv, hd)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, kv, hd)) * 0.5, jnp.float32)
    q_pos = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0) + (t - s)
    k_pos = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    return q, k, v, q_pos, k_pos


def _oracle(q, k, v, q_pos, k_pos, causal, window, softcap, scale):
    g = q.shape[2] // k.shape[2]
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    return _attend_direct(q, kk, vv, q_pos, k_pos, causal=causal, window=window,
                          softcap=softcap, scale=scale)


CASES = [
    dict(causal=True, window=None, softcap=None),
    dict(causal=True, window=16, softcap=None),
    dict(causal=True, window=None, softcap=20.0),
    dict(causal=False, window=None, softcap=None),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 16)])
def test_forward_matches_oracle(case, bq, bk):
    q, k, v, qp, kp = _inputs()
    scale = q.shape[-1] ** -0.5
    got = flash_attention(q, k, v, qp, kp, case["causal"], case["window"],
                          case["softcap"], scale, bq, bk, True)
    want = _oracle(q, k, v, qp, kp, scale=scale, **case)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 1), (8, 2)])
def test_gqa_head_mapping(h, kv):
    q, k, v, qp, kp = _inputs(h=h, kv=kv, seed=h * 10 + kv)
    scale = q.shape[-1] ** -0.5
    got = flash_attention(q, k, v, qp, kp, True, None, None, scale, 32, 32, True)
    want = _oracle(q, k, v, qp, kp, causal=True, window=None, softcap=None, scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_masked_cache_slots():
    """k_pos = -1 marks unwritten cache slots; they must not attend."""
    q, k, v, qp, kp = _inputs(s=16, t=64)
    kp = jnp.where(kp < 40, kp, -1)  # only 40 valid slots
    qp = jnp.minimum(qp, 39)
    scale = q.shape[-1] ** -0.5
    got = flash_attention(q, k, v, qp, kp, True, None, None, scale, 16, 32, True)
    want = _oracle(q, k, v, qp, kp, causal=True, window=None, softcap=None, scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", CASES[:3])
def test_gradients_match_oracle(case):
    q, k, v, qp, kp = _inputs(b=1, s=32, t=32, h=2, kv=1, hd=16)
    scale = q.shape[-1] ** -0.5

    def loss_kernel(q, k, v):
        o = flash_attention(q, k, v, qp, kp, case["causal"], case["window"],
                            case["softcap"], scale, 16, 16, True)
        return jnp.sum(o * jnp.cos(o))

    def loss_oracle(q, k, v):
        o = _oracle(q, k, v, qp, kp, scale=scale, **case)
        return jnp.sum(o * jnp.cos(o))

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_oracle, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_bf16_inputs():
    q, k, v, qp, kp = _inputs()
    scale = q.shape[-1] ** -0.5
    got = flash_attention(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                          v.astype(jnp.bfloat16), qp, kp, True, None, None,
                          scale, 32, 32, True)
    want = _oracle(q, k, v, qp, kp, causal=True, window=None, softcap=None, scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)
