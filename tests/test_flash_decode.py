"""flash_decode kernel vs the decode-path oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_decode
from repro.models.attention import _attend_direct


def _case(b=2, t=64, h=4, kv=2, hd=32, valid=40, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, hd)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, kv, hd)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, kv, hd)) * 0.5, jnp.float32)
    k_pos = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    k_pos = jnp.where(k_pos < valid, k_pos, -1)  # unwritten cache slots
    q_pos = jnp.full((b,), valid - 1, jnp.int32)
    return q, k, v, q_pos, k_pos


def _oracle(q, k, v, q_pos, k_pos, window, softcap, scale):
    g = q.shape[1] // k.shape[2]
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    out = _attend_direct(q[:, None], kk, vv, q_pos[:, None], k_pos,
                         causal=True, window=window, softcap=softcap, scale=scale)
    return out[:, 0]


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 1), (8, 2)])
@pytest.mark.parametrize("window,softcap", [(None, None), (16, None), (None, 20.0)])
@pytest.mark.parametrize("bk", [16, 64])
def test_flash_decode_matches_oracle(h, kv, window, softcap, bk):
    q, k, v, q_pos, k_pos = _case(h=h, kv=kv, seed=h + kv)
    scale = q.shape[-1] ** -0.5
    got = flash_decode(q, k, v, q_pos, k_pos, window=window, softcap=softcap,
                       scale=scale, bk=bk, interpret=True)
    want = _oracle(q, k, v, q_pos, k_pos, window, softcap, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_bf16_cache():
    q, k, v, q_pos, k_pos = _case(seed=7)
    scale = q.shape[-1] ** -0.5
    got = flash_decode(q.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                       v.astype(jnp.bfloat16), q_pos, k_pos, scale=scale,
                       bk=32, interpret=True)
    want = _oracle(q, k, v, q_pos, k_pos, None, None, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)
