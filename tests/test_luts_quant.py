"""Product/error LUTs, SVD factors, quantization round-trips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import luts, quantization, seqmul


def test_product_lut_matches_simulator():
    n, t = 6, 3
    lut = luts.product_lut(n, t)
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1 << n, size=200, dtype=np.uint32)
    b = rng.integers(0, 1 << n, size=200, dtype=np.uint32)
    w = seqmul.seq_mul_words(a, b, n=n, t=t, approx=True, fix_to_1=True)
    expect = seqmul.assemble_product_u64(w, n=n, t=t)
    np.testing.assert_array_equal(lut[a, b], expect.astype(np.int32))


def test_error_lut_is_difference():
    n, t = 5, 2
    v = np.arange(1 << n)
    exact = np.multiply.outer(v, v)
    np.testing.assert_array_equal(
        luts.error_lut(n, t) + exact, luts.product_lut(n, t)
    )


def test_svd_factors_reconstruct():
    n, t = 6, 3
    e = luts.error_lut(n, t).astype(np.float64)
    u, v, energy = luts.svd_error_factors(n, t, rank=1 << n)  # full rank
    assert energy == pytest.approx(1.0)
    np.testing.assert_allclose(u @ v.T, e, atol=1e-3)
    # truncation keeps the reported energy fraction
    u8, v8, en8 = luts.svd_error_factors(n, t, rank=8)
    approx = u8 @ v8.T
    resid = np.linalg.norm(e - approx) ** 2 / max(np.linalg.norm(e) ** 2, 1e-9)
    assert resid == pytest.approx(1 - en8, abs=1e-6)
    assert 0.5 < en8 <= 1.0  # rank-8 captures most error energy at n=6


def test_lut_stats_and_cap():
    s = luts.lut_stats(8, 4)
    assert s["vmem_bytes_product_lut"] == 4 * (1 << 16)
    assert 0 < s["nonzero_frac"] < 1
    with pytest.raises(ValueError):
        luts.product_lut(12, 4)


def test_quantize_roundtrip():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    qp = quantization.calibrate_absmax(x, bits=8)
    mag, sign = quantization.quantize(x, qp)
    assert mag.dtype == jnp.uint32
    assert int(mag.max()) <= 255
    back = quantization.dequantize(mag, sign, qp)
    err = np.abs(np.asarray(back - x))
    assert err.max() <= float(qp.scale) * 0.5 + 1e-6


def test_fake_quant_ste_gradient():
    x = jnp.linspace(-1.0, 1.0, 16)
    g = jax.grad(lambda v: quantization.fake_quant(v, bits=4).sum())(x)
    # straight-through on interior elements (the abs-max endpoints also
    # receive the d(scale)/dx term, by design)
    np.testing.assert_allclose(np.asarray(g)[1:-1], 1.0)


def test_per_axis_calibration():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((4, 128)) * np.array([[1], [10], [100], [1000]]),
                    jnp.float32)
    qp = quantization.calibrate_absmax(x, bits=8, axis=1)
    assert qp.scale.shape == (4, 1)
    xq = quantization.fake_quant(x, bits=8, axis=1)
    rel = np.abs(np.asarray(xq - x)) / np.maximum(np.abs(np.asarray(x)), 1e-3)
    assert np.median(rel) < 0.05
