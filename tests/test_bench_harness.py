"""Benchmark harness: registry discovery, BENCH_*.json schema, --compare gating.

Uses the ``fig3_latency_area`` suite throughout — closed-form gate-delay
models, so rows are deterministic and instantaneous, which lets the
compare tests assert exact regression/no-regression outcomes.
"""

import copy
import json

import pytest

from benchmarks import harness, registry

SUITE = "fig3_latency_area"
EXPECTED_SUITES = {
    "engine_matmul",
    "fig2_error_metrics",
    "fig3_latency_area",
    "accuracy_pareto",
    "gemm_modes",
    "roofline",
    "serve_soak",
    "serve_throughput",
    "speculative",
}


@pytest.fixture(scope="module")
def doc():
    return harness.run_suite(registry.get_suite(SUITE), reduced=True)


def test_registry_discovers_all_suites():
    assert set(registry.discover()) == EXPECTED_SUITES


def test_run_shim_derives_from_registry():
    from benchmarks import run

    assert {name for name, _ in run.modules()} == set(registry.discover())


def test_unknown_suite_lists_valid_names():
    with pytest.raises(ValueError, match="engine_matmul"):
        registry.get_suite("nope")


def test_emitted_json_is_schema_valid(doc, tmp_path):
    path = harness.write_doc(doc, str(tmp_path))
    assert path.endswith(f"BENCH_{SUITE}.json")
    loaded = harness.load_doc(path)  # load_doc validates
    assert loaded["suite"] == SUITE
    assert loaded["schema_version"] == harness.SCHEMA_VERSION
    assert loaded["reduced"] is True
    assert loaded["row_count"] == len(loaded["rows"]) > 0
    assert loaded["git_sha"]
    for key in ("python", "jax", "numpy", "jax_backend", "device_count", "platform"):
        assert key in loaded["env"]
    assert loaded["gating"]["key_fields"] == ["table", "n", "t"]
    assert all("table" in row for row in loaded["rows"])


def test_validate_doc_rejects_malformed(doc):
    with pytest.raises(ValueError, match="missing key"):
        harness.validate_doc({})
    bad = copy.deepcopy(doc)
    bad["schema_version"] = 99
    with pytest.raises(ValueError, match="schema_version"):
        harness.validate_doc(bad)
    bad = copy.deepcopy(doc)
    bad["row_count"] += 1
    with pytest.raises(ValueError, match="row_count"):
        harness.validate_doc(bad)
    bad = copy.deepcopy(doc)
    del bad["rows"][0]["table"]
    with pytest.raises(ValueError, match="'table'"):
        harness.validate_doc(bad)


def test_compare_identical_runs_has_no_regressions(doc):
    assert harness.compare_docs(doc, copy.deepcopy(doc)) == []


def test_compare_flags_doctored_faster_baseline(doc):
    baseline = copy.deepcopy(doc)
    for row in baseline["rows"]:
        if "latency_approx" in row:  # lower-is-better: baseline was "faster"
            row["latency_approx"] *= 0.5
        if "avg_latency_reduction_pct" in row:  # higher-is-better: baseline "won more"
            row["avg_latency_reduction_pct"] *= 2.0
    regs = harness.compare_docs(doc, baseline, threshold=0.25)
    assert regs
    assert {r.direction for r in regs} == {"lower_is_better", "higher_is_better"}
    assert all(r.rel_change > 0.25 for r in regs)


def test_compare_within_threshold_passes(doc):
    baseline = copy.deepcopy(doc)
    for row in baseline["rows"]:
        if "latency_approx" in row:
            row["latency_approx"] *= 0.9  # 11% worse now: under the 25% gate
    assert harness.compare_docs(doc, baseline, threshold=0.25) == []


def test_compare_rejects_mismatched_runs(doc):
    other = copy.deepcopy(doc)
    other["suite"] = "engine_matmul"
    with pytest.raises(ValueError, match="cannot compare suite"):
        harness.compare_docs(doc, other)
    other = copy.deepcopy(doc)
    other["reduced"] = False
    with pytest.raises(ValueError, match="reduced"):
        harness.compare_docs(doc, other)


def test_new_rows_are_not_regressions(doc):
    baseline = copy.deepcopy(doc)
    baseline["rows"] = baseline["rows"][:1]
    baseline["row_count"] = 1
    assert harness.compare_docs(doc, baseline) == []


def test_vanished_rows_are_regressions(doc):
    current = copy.deepcopy(doc)
    current["rows"] = current["rows"][:-1]
    current["row_count"] -= 1
    regs = harness.compare_docs(current, doc)
    assert len(regs) == 1
    assert regs[0].direction == "missing_row"
    assert regs[0].metric == "row_present"


def test_cli_run_write_and_gate(doc, tmp_path):
    out = tmp_path / "out"
    out.mkdir()
    clean = tmp_path / "BENCH_clean.json"
    clean.write_text(json.dumps(doc, default=float))
    rc = harness.main([
        "--suite", SUITE, "--reduced", "--out-dir", str(out),
        "--compare", str(clean),
    ])
    assert rc == 0
    assert (out / f"BENCH_{SUITE}.json").exists()

    doctored = copy.deepcopy(doc)
    for row in doctored["rows"]:
        if "latency_approx" in row:
            row["latency_approx"] *= 0.5
    bad = tmp_path / "BENCH_doctored.json"
    bad.write_text(json.dumps(doctored, default=float))
    rc = harness.main([
        "--suite", SUITE, "--reduced", "--out-dir", str(out),
        "--compare", str(bad),
    ])
    assert rc == 1
