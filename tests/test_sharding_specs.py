"""Sharding rules: divisibility degradation, param-path rules, batch and
cache spec trees (pure functions — no multi-device runtime needed)."""

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.distributed.sharding import (
    _resolve_entry, param_spec, param_specs, resolve_spec,
)
from repro.models.registry import build_model

SIZES = {"pod": 2, "data": 16, "model": 16}


def test_resolve_entry_divisibility():
    assert _resolve_entry("model", 64, SIZES) == "model"
    assert _resolve_entry("model", 28, SIZES) is None  # 28 % 16 != 0
    assert _resolve_entry(("pod", "data"), 256, SIZES) == ("pod", "data")
    assert _resolve_entry(("pod", "data"), 2, SIZES) == "pod"  # prefix shrink
    assert _resolve_entry(("pod", "data"), 3, SIZES) is None
    assert _resolve_entry("absent", 64, SIZES) is None


def test_resolve_spec_shapes():
    spec = resolve_spec((("pod", "data"), None, "model"), (256, 7, 4096), SIZES)
    assert spec == P(("pod", "data"), None, "model")


def test_param_spec_rules():
    # embed (vocab, d): vocab TP + d FSDP
    assert param_spec("embed", (64000, 4096), SIZES) == P("model", "data")
    # granite vocab 49155 not divisible -> vocab replicated, d sharded
    assert param_spec("embed", (49155, 1024), SIZES) == P(None, "data")
    # attention projections
    assert param_spec("scan/sub0/attn/wq", (4096, 4096), SIZES) == P("data", "model")
    assert param_spec("scan/sub0/attn/wo", (4096, 4096), SIZES) == P("model", "data")
    # scanned leading dim stays unsharded
    assert param_spec("scan/sub0/ffn/w1", (12, 4096, 11008), SIZES) == P(None, "data", "model")
    # MoE experts over TP
    assert param_spec("scan/sub0/ffn_moe/we1", (32, 1024, 512), SIZES)[0] == "model"
    # norms replicate
    assert param_spec("scan/sub0/ln1", (4096,), SIZES) == P()


def test_param_specs_cover_all_archs():
    """Every parameter of every arch gets a spec without error, and large
    matrices are sharded on at least one axis (fits-at-scale proxy)."""
    for arch in ["yi-9b", "kimi-k2-1t-a32b", "mamba2-130m", "seamless-m4t-large-v2"]:
        cfg = get_config(arch)
        m = build_model(cfg)
        shapes = jax.eval_shape(lambda m=m: m.init_params(jax.random.PRNGKey(0)))
        mesh_sizes = SIZES

        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        import re

        def pstr(kp):
            return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)

        for kp, v in flat:
            spec = param_spec(pstr(kp), v.shape, mesh_sizes)
            assert len(spec) <= len(v.shape)  # trailing dims implicitly replicated
            if v.size >= (1 << 24):  # >= 16M elements must be sharded
                assert any(s is not None for s in spec), (arch, pstr(kp), v.shape)
