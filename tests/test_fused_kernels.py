"""Fused-kernel parity sweep (ISSUE 6).

Asserts the tiled Pallas GEMM bodies bit-match their pure-jnp reference
oracles across mode × tier-resolved t × n ∈ {4, 8} × shape (including
ragged non-tile-multiple M/K/N), that the straight-through custom_vjp
routes exact-matmul gradients through the fused bodies, that the n=16
two-word seqmul path matches the core oracle, that the LUT gather clamp
survives adversarial out-of-range magnitudes, and that the fused
approximate attention kernel matches its blockwise reference op for op.

Everything runs in interpret mode on CPU (the engine policy's default
off-TPU) — this is the `kernel-parity` CI step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import seqmul as core_seqmul

SHAPES = [(16, 32, 16), (17, 33, 19)]  # tile-multiple-ish and ragged
FUSED_MODES = ["bitexact", "lowrank", "seqmul", "inject"]


def _operands(m, k, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    return x, w


def _matmul(x, w, mode, backend, *, n, t):
    kw = dict(mode=mode, backend=backend, n=n, t=t)
    if engine.get_mode(mode).needs_key:
        kw["key"] = jax.random.PRNGKey(7)
    return np.asarray(engine.matmul(x, w, **kw))


# ------------------------------------------------------------ GEMM parity
def _assert_parity(mode, ref, pal):
    if mode == "lowrank":
        # the SVD correction term is float-valued, so the tiled K-blocked
        # reduction tree can differ from the reference einsum by ulps;
        # every other fused mode accumulates integer-valued f32 and is
        # bit-exact by construction
        np.testing.assert_allclose(ref, pal, rtol=2e-6, atol=2e-6)
    else:
        np.testing.assert_array_equal(ref, pal)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("n_bits", [4, 8])
@pytest.mark.parametrize("mode", FUSED_MODES)
def test_fused_gemm_bitmatches_reference(mode, n_bits, shape):
    x, w = _operands(*shape)
    t = engine.config.default_t(n_bits)
    ref = _matmul(x, w, mode, "reference", n=n_bits, t=t)
    pal = _matmul(x, w, mode, "pallas", n=n_bits, t=t)
    _assert_parity(mode, ref, pal)


@pytest.mark.parametrize("tier", ["high", "balanced", "draft"])
def test_fused_gemm_parity_at_tier_resolutions(tier):
    """Every tier's mlp-class (mode, n, t) selection runs fused and
    bit-matches its reference oracle."""
    qc = engine.resolve_tier(tier)
    sel = next(q for q in qc.per_target if q.target == "mlp")
    if engine.get_mode(sel.mode).pallas is None:
        pytest.skip(f"tier {tier} mode {sel.mode} has no pallas body")
    x, w = _operands(17, 33, 19, seed=3)
    ref = _matmul(x, w, sel.mode, "reference", n=sel.n, t=sel.t)
    pal = _matmul(x, w, sel.mode, "pallas", n=sel.n, t=sel.t)
    _assert_parity(sel.mode, ref, pal)


def test_seqmul_gemm_oracle_matches_lut_semantics():
    """The fused-recurrence GEMM and the LUT GEMM implement the same
    multiplier: at n <= 8 their integer accumulations are identical."""
    rng = np.random.default_rng(5)
    ma = jnp.asarray(rng.integers(0, 256, (9, 13)), jnp.uint32)
    mb = jnp.asarray(rng.integers(0, 256, (13, 7)), jnp.uint32)
    sa = jnp.asarray(rng.choice([-1, 1], (9, 13)), jnp.int8)
    sb = jnp.asarray(rng.choice([-1, 1], (13, 7)), jnp.int8)
    via_lut = engine.bitexact_gemm_int(ma, sa, mb, sb, n=8, t=4)
    via_rec = engine.seqmul_gemm_int(ma, sa, mb, sb, n=8, t=4)
    np.testing.assert_array_equal(np.asarray(via_lut), np.asarray(via_rec))


@pytest.mark.parametrize("mode", ["seqmul", "inject"])
def test_straight_through_grads_route_through_fused_bodies(mode):
    """Non-differentiable fused modes get exact-matmul gradients, bit-equal
    between backends (the custom_vjp backward never touches the kernel)."""
    x, w = _operands(8, 16, 8, seed=1)

    def loss(backend):
        def f(x, w):
            kw = dict(mode=mode, backend=backend, n=8, t=4)
            if engine.get_mode(mode).needs_key:
                kw["key"] = jax.random.PRNGKey(7)
            return engine.matmul(x, w, **kw).sum()
        return jax.grad(f, argnums=(0, 1))(x, w)

    gx_ref, gw_ref = loss("reference")
    gx_pal, gw_pal = loss("pallas")
    np.testing.assert_array_equal(np.asarray(gx_ref), np.asarray(gx_pal))
    np.testing.assert_array_equal(np.asarray(gw_ref), np.asarray(gw_pal))
    # straight-through == exact matmul backward
    np.testing.assert_allclose(
        np.asarray(gx_pal), np.asarray(jnp.ones((8, 8)) @ w.T), rtol=1e-6)


# -------------------------------------------------- n=16 two-word packing
@pytest.mark.parametrize("approx", [True, False])
@pytest.mark.parametrize("n_t", [(16, 8), (16, 12), (12, 6)])
def test_seqmul_words_matches_core_oracle(n_t, approx):
    n, t = n_t
    from repro.kernels.seqmul_kernel import seqmul_pallas_words

    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, 1 << n, (257,)), jnp.uint32)
    b = jnp.asarray(rng.integers(0, 1 << n, (257,)), jnp.uint32)
    lo, hi = seqmul_pallas_words(a, b, n=n, t=t, approx=approx)
    got = np.asarray(lo, np.uint64) + (np.asarray(hi, np.uint64) << np.uint64(n))
    words = core_seqmul.seq_mul_words(a, b, n=n, t=t, approx=approx)
    want = core_seqmul.assemble_product_u64(words, n=n, t=t)
    np.testing.assert_array_equal(got, want)


def test_dispatch_validates_eagerly():
    x, w = _operands(4, 8, 4)
    with pytest.raises(ValueError, match="bitexact.*n <= 8"):
        engine.matmul(x, w, mode="bitexact", n=9, t=4)
    with pytest.raises(ValueError, match="seqmul.*n <= 12"):
        engine.matmul(x, w, mode="seqmul", n=13, t=4)
    with pytest.raises(ValueError, match="mode 'seqmul'"):
        engine.matmul(x, w, mode="seqmul", n=8, t=9)  # t > n invalid
    a = jnp.zeros((4,), jnp.uint32)
    with pytest.raises(ValueError, match="seqmul_pallas_words"):
        engine.multiply(a, a, n=16, t=8)


# --------------------------------------------------------- LUT gather clamp
def test_lut_gather_clamps_adversarial_magnitudes():
    """Out-of-range quantized magnitudes (upstream bug / adversarial
    operands) must saturate to the table edge, not gather another row's
    products or out-of-bounds VMEM."""
    from repro.kernels.lut_matmul import lut_matmul_pallas

    n = 4
    lut = engine.artifacts.product_lut_flat(n, 2)
    rng = np.random.default_rng(8)
    # magnitudes way past 2^n - 1, including values whose idx would land
    # in other rows of the flattened table
    ma = jnp.asarray(rng.integers(0, 1 << 8, (9, 11)), jnp.uint32)
    mb = jnp.asarray(rng.integers(0, 1 << 8, (11, 5)), jnp.uint32)
    sa = jnp.asarray(rng.choice([-1.0, 1.0], (9, 11)), jnp.float32)
    sb = jnp.asarray(rng.choice([-1.0, 1.0], (11, 5)), jnp.float32)
    out = np.asarray(lut_matmul_pallas(lut, ma, sa, mb, sb, n=n, bm=8, bn=8, bk=8))
    qmax = (1 << n) - 1
    want = np.asarray(engine.bitexact_gemm_int(
        jnp.minimum(ma, qmax), sa.astype(jnp.int8),
        jnp.minimum(mb, qmax), sb.astype(jnp.int8), n=n, t=2))
    np.testing.assert_array_equal(out, want)
    assert np.isfinite(out).all()


# ------------------------------------------------------- fused attention
ATTN_SHAPES = [
    # (B, S, T, H, KV, HD) — tile-multiple and ragged
    (1, 16, 16, 2, 2, 16),
    (2, 24, 24, 4, 2, 16),  # ragged vs bq=bk=16, GQA g=2
]


def _attn_inputs(b, s, t, h, kv, hd, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, kv, hd)), jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    kp = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    return q, k, v, qp, kp


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("mode", ["bitexact", "lowrank"])
def test_approx_attention_bitmatches_blockwise_reference(mode, shape):
    from repro.kernels.approx_attention import (
        approx_attention_reference, approx_flash_attention)

    q, k, v, qp, kp = _attn_inputs(*shape)
    hd = q.shape[-1]
    kern = approx_flash_attention(
        q, k, v, qp, kp, mode, 8, 4, True, 4, True, None, None,
        hd**-0.5, 16, 16, True)
    # the reference mirrors the kernel op for op; jitting it makes XLA
    # fuse both identically, so the comparison is bit-exact
    ref = jax.jit(functools.partial(
        approx_attention_reference, mode=mode, n=8, t=4, rank=4,
        causal=True, scale=hd**-0.5, bq=16, bk=16))(q, k, v, qp, kp)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(ref))


def test_approx_attention_window_and_softcap():
    from repro.kernels.approx_attention import (
        approx_attention_reference, approx_flash_attention)

    q, k, v, qp, kp = _attn_inputs(1, 16, 16, 2, 1, 16, seed=4)
    kern = approx_flash_attention(
        q, k, v, qp, kp, "lowrank", 8, 4, True, 4, True, 8, 20.0,
        0.25, 8, 8, True)
    ref = jax.jit(functools.partial(
        approx_attention_reference, mode="lowrank", n=8, t=4, rank=4,
        causal=True, window=8, softcap=20.0, scale=0.25, bq=8, bk=8))(
        q, k, v, qp, kp)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(ref))


def test_approx_attention_error_grows_with_t():
    """Paper semantics inside the attention kernel: deferring a heavier
    carry (larger t) must not shrink the output error."""
    from repro.kernels.approx_attention import approx_flash_attention
    from repro.kernels.flash_attention import flash_attention

    q, k, v, qp, kp = _attn_inputs(1, 16, 16, 2, 2, 16, seed=6)
    exact = np.asarray(flash_attention(
        q, k, v, qp, kp, True, None, None, 0.25, 16, 16, True))

    def err(t):
        o = np.asarray(approx_flash_attention(
            q, k, v, qp, kp, "bitexact", 8, t, True, 4, True, None, None,
            0.25, 16, 16, True))
        return np.linalg.norm(o - exact)

    assert err(2) <= err(7) * 1.001


def test_approx_attention_straight_through_grads():
    from repro.kernels.approx_attention import approx_flash_attention
    from repro.kernels.flash_attention import flash_attention

    q, k, v, qp, kp = _attn_inputs(1, 16, 16, 2, 2, 16, seed=9)

    def loss(q, k, v):
        return approx_flash_attention(
            q, k, v, qp, kp, "lowrank", 8, 2, True, 8, True, None, None,
            0.25, 16, 16, True).sum()

    def exact_loss(q, k, v):
        return flash_attention(
            q, k, v, qp, kp, True, None, None, 0.25, 16, 16, True).sum()

    grads = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    exact_grads = jax.grad(exact_loss, argnums=(0, 1, 2))(q, k, v)
    for g, eg in zip(grads, exact_grads):
        g, eg = np.asarray(g), np.asarray(eg)
        assert np.isfinite(g).all()
        cos = (g.ravel() @ eg.ravel()) / (
            np.linalg.norm(g) * np.linalg.norm(eg) + 1e-30)
        assert cos > 0.95, cos


def test_attention_layer_routes_fused_approx():
    """models.attention picks the fused approximate kernel when the attn
    target is approximated under attn_impl='pallas'."""
    import dataclasses

    from repro.configs.base import ApproxConfig, ModelConfig
    from repro.models import attention as attn_mod
    from repro.models.layers import Ctx

    cfg = ModelConfig(
        name="tiny", family="test", d_model=32, num_heads=4, num_kv_heads=2,
        head_dim=8, d_ff=64, vocab_size=128, num_layers=1,
        attn_impl="pallas",
        approx=ApproxConfig(enabled=True, mode="lowrank",
                            targets=("attn",), n=8, t=4, rank=4))
    params = attn_mod.init_attn(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32), (2, 16))
    out, _ = attn_mod.attention(params, x, pos, Ctx(cfg=cfg))
    assert out.shape == (2, 16, 32)
    assert bool(jnp.isfinite(out).all())
    # and the approximation actually changed the output vs exact
    cfg2 = dataclasses.replace(cfg, approx=ApproxConfig(enabled=False))
    out2, _ = attn_mod.attention(params, x, pos, Ctx(cfg=cfg2))
    assert not np.allclose(np.asarray(out), np.asarray(out2))
