"""Continuous-batching scheduler correctness (repro.serve).

Three properties pin the subsystem down:

* **Parity** — with full-length prompts and uniform budgets (no padding,
  no retirement churn) the continuous scheduler must bit-match the
  static-batch loop: same greedy token streams for the same seed/queue.
* **True-position correctness** — with *mixed* prompt lengths, every
  request's stream must bit-match the request served alone, unpadded
  (batch 1, bucket == its true length).  The static loop fails this by
  construction (all rows share the ``arange`` position ids); the per-row
  position vectors are the fix.
* **Per-row retirement** — a 3-prompt queue on 2 slots must admit the
  third request mid-stream (``admit_step > 0``) without re-prefilling
  the surviving row, and still serve everyone their budgeted tokens.
"""

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.registry import build_model
from repro.serve import (
    ContinuousScheduler,
    Request,
    continuous_serve_loop,
    static_serve_loop,
    synth_requests,
)

PROMPT, GEN = 8, 4


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen3-0.6b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def test_parity_with_static_batch_loop(served):
    """No padding, uniform budgets: continuous ≡ static, bit for bit."""
    cfg, model, params = served
    rng = np.random.default_rng(3)
    queue = [
        Request(id=i, tokens=rng.integers(0, cfg.vocab_size, PROMPT).astype(np.int32),
                max_new=GEN)
        for i in range(4)
    ]
    static = static_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, gen=GEN, warmup=False
    )
    cont = continuous_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, max_new=GEN, warmup=False
    )
    assert static.stats.tokens_out == cont.stats.tokens_out == 4 * GEN
    for r in queue:
        np.testing.assert_array_equal(
            static.outputs[r.id], cont.outputs[r.id],
            err_msg=f"request {r.id}: continuous diverged from the static loop",
        )


def test_padded_rows_decode_at_true_positions(served):
    """Mixed lengths: every stream == the request served alone, unpadded."""
    cfg, model, params = served
    queue = synth_requests(
        6, prompt_len=PROMPT, gen=GEN, vocab_size=cfg.vocab_size, seed=0
    )
    assert len({r.prompt_len for r in queue}) > 1, "workload must mix lengths"
    cont = continuous_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, max_new=GEN, warmup=False
    )
    for r in queue:
        alone = static_serve_loop(
            model, params, [r], batch_size=1, prompt_len=r.prompt_len,
            gen=r.max_new, warmup=False,
        )
        np.testing.assert_array_equal(
            alone.outputs[r.id], cont.outputs[r.id],
            err_msg=f"request {r.id} (len {r.prompt_len}): padded decode diverged "
                    f"from the unpadded single-request run",
        )


def test_third_request_admitted_mid_stream(served):
    """3 prompts on 2 slots: the third is admitted once a row retires."""
    cfg, model, params = served
    rng = np.random.default_rng(7)
    queue = [
        Request(id=0, tokens=rng.integers(0, cfg.vocab_size, 6).astype(np.int32), max_new=2),
        Request(id=1, tokens=rng.integers(0, cfg.vocab_size, PROMPT).astype(np.int32), max_new=GEN),
        Request(id=2, tokens=rng.integers(0, cfg.vocab_size, 5).astype(np.int32), max_new=2),
    ]
    cont = continuous_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, max_new=GEN, warmup=False
    )
    assert cont.stats.requests == 3
    assert cont.stats_for(0).admit_step == 0
    assert cont.stats_for(1).admit_step == 0
    third = cont.stats_for(2)
    assert third.admit_step > 0, "third request must be admitted mid-stream"
    for r in queue:
        assert len(cont.outputs[r.id]) == r.max_new
        assert cont.stats_for(r.id).finish_reason == "budget"
    # the admission must not have re-prefilled (or perturbed) the survivor:
    alone = static_serve_loop(
        model, params, [queue[1]], batch_size=1, prompt_len=PROMPT, gen=GEN, warmup=False
    )
    np.testing.assert_array_equal(alone.outputs[1], cont.outputs[1])


def test_eos_retires_early(served):
    """A row emitting its eos_id retires before its budget."""
    cfg, model, params = served
    rng = np.random.default_rng(11)
    toks = rng.integers(0, cfg.vocab_size, PROMPT).astype(np.int32)
    # find the first greedy token, then use it as the EOS id
    probe = continuous_serve_loop(
        model, params, [Request(id=0, tokens=toks, max_new=GEN)],
        batch_size=1, prompt_len=PROMPT, max_new=GEN, warmup=False,
    )
    eos = int(probe.outputs[0][0])
    cont = continuous_serve_loop(
        model, params, [Request(id=0, tokens=toks, max_new=GEN, eos_id=eos)],
        batch_size=1, prompt_len=PROMPT, max_new=GEN, warmup=False,
    )
    assert cont.stats_for(0).finish_reason == "eos"
    assert cont.stats_for(0).tokens_out == 1
    assert cont.stats.decode_steps == 0


def test_slot_utilization_and_stats_surface(served):
    cfg, model, params = served
    queue = synth_requests(
        5, prompt_len=PROMPT, gen=GEN, vocab_size=cfg.vocab_size, seed=1
    )
    cont = continuous_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, max_new=GEN, warmup=False
    )
    s = cont.stats
    assert s.scheduler == "continuous"
    assert 0.0 < s.slot_utilization <= 1.0
    assert len(s.ttft_s) == len(s.request_latencies_s) == 5
    assert all(t > 0 for t in s.ttft_s)
    assert all(l >= t for l, t in zip(s.request_latencies_s, s.ttft_s))
    assert s.tokens_out == sum(r.max_new for r in queue)
    assert "continuous" in s.summary()


def test_admission_rejects_oversized_requests(served):
    cfg, model, params = served
    sched = ContinuousScheduler(
        model, params, batch_size=1, prompt_len=4, max_new=2
    )
    too_long = Request(id=0, tokens=np.zeros(5, np.int32), max_new=1)
    with pytest.raises(ValueError, match="exceeds bucket"):
        sched.run([too_long], warmup=False)
    too_greedy = Request(id=0, tokens=np.zeros(4, np.int32), max_new=3)
    with pytest.raises(ValueError, match="exceeds slot capacity"):
        sched.run([too_greedy], warmup=False)


def test_recurrent_family_rejects_padded_admission():
    """RG-LRU/SSD state integrates left pads (positions cannot mask it),
    so padded admission must raise instead of silently decoding wrong —
    full-length prompts still serve fine."""
    cfg = get_config("mamba2-130m").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    short = Request(id=0, tokens=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                    max_new=2)
    with pytest.raises(ValueError, match="recurrent-state"):
        continuous_serve_loop(model, params, [short], batch_size=1,
                              prompt_len=8, max_new=2, warmup=False)
    full = Request(id=1, tokens=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                   max_new=2)
    res = continuous_serve_loop(model, params, [full], batch_size=1,
                                prompt_len=8, max_new=2, warmup=False)
    assert res.stats_for(1).tokens_out == 2


def test_encdec_rejected():
    cfg = get_config("seamless-m4t-large-v2").reduced()
    model = build_model(cfg)
    with pytest.raises(ValueError, match="decoder-only"):
        ContinuousScheduler(model, params=None, batch_size=1, prompt_len=4, max_new=2)


def test_quality_tier_parity_and_stats(served):
    """Per-tier serving: the pool resolves the tier to an engine config
    (controller-selected per-GEMM-class splits) and the continuous
    scheduler still bit-matches the static loop at that tier."""
    cfg, model, params = served
    rng = np.random.default_rng(13)
    queue = [
        Request(id=i, tokens=rng.integers(0, cfg.vocab_size, PROMPT).astype(np.int32),
                max_new=GEN, quality="balanced")
        for i in range(2)
    ]
    static = static_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, gen=GEN,
        warmup=False, quality="balanced",
    )
    cont = continuous_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, max_new=GEN,
        warmup=False, quality="balanced",
    )
    assert static.stats.quality == cont.stats.quality == "balanced"
    assert "tier balanced" in cont.stats.summary()
    for r in queue:
        np.testing.assert_array_equal(
            static.outputs[r.id], cont.outputs[r.id],
            err_msg=f"request {r.id}: tier-resolved continuous diverged from static",
        )
    # the tier actually changes the computation vs the unconfigured pool
    plain = continuous_serve_loop(
        model, params,
        [Request(id=r.id, tokens=r.tokens, max_new=r.max_new) for r in queue],
        batch_size=2, prompt_len=PROMPT, max_new=GEN, warmup=False,
    )
    assert any(
        not np.array_equal(plain.outputs[r.id], cont.outputs[r.id]) for r in queue
    ), "balanced tier produced bit-identical streams to the exact pool"


def test_quality_tier_mismatch_rejected_at_admission(served):
    cfg, model, params = served
    req_high = Request(id=0, tokens=np.zeros(4, np.int32), max_new=1, quality="high")
    with pytest.raises(ValueError, match="serves 'balanced'"):
        continuous_serve_loop(
            model, params, [req_high], batch_size=1, prompt_len=PROMPT,
            max_new=GEN, warmup=False, quality="balanced",
        )
    with pytest.raises(ValueError, match="without one"):
        continuous_serve_loop(
            model, params, [req_high], batch_size=1, prompt_len=PROMPT,
            max_new=GEN, warmup=False,
        )
    with pytest.raises(ValueError, match="unknown quality tier"):
        ContinuousScheduler(
            model, params, batch_size=1, prompt_len=PROMPT, max_new=GEN,
            quality="no-such-tier",
        )
    # untagged requests ride on any pool; tagged ones match their pool
    ok = continuous_serve_loop(
        model, params,
        [Request(id=1, tokens=np.zeros(4, np.int32), max_new=1),
         Request(id=2, tokens=np.zeros(4, np.int32), max_new=1, quality="high")],
        batch_size=1, prompt_len=PROMPT, max_new=GEN, warmup=False, quality="high",
    )
    assert ok.stats.requests == 2


def test_empty_distribution_summary_renders_na():
    """percentile() returns None on empty input — summary() must say n/a,
    not a misleading 'ttft p50 0ms', when nothing retired."""
    from repro.serve.stats import ServeStats, fmt_ms

    empty = ServeStats(
        requests=0, tokens_out=0, wall_s=0.0, prefill_s=0.0, decode_s=0.0,
        batch_latencies_s=(), devices=1, scheduler="continuous",
    )
    assert "ttft p50 n/a" in empty.summary()
    assert "0ms" not in empty.summary()
    assert fmt_ms((), 50) == "n/a"
    assert fmt_ms((0.1,), 50) == "100ms"
    full = ServeStats(
        requests=1, tokens_out=1, wall_s=1.0, prefill_s=0.0, decode_s=1.0,
        batch_latencies_s=(), devices=1, scheduler="continuous",
        ttft_s=(0.25,),
    )
    assert "ttft p50 250ms" in full.summary()


def test_data_parallel_mesh_helper():
    from repro.distributed.sharding import data_parallel_mesh

    # single device: no mesh, serving runs unsharded
    if jax.device_count() == 1:
        assert data_parallel_mesh(4) is None
    else:
        mesh = data_parallel_mesh(jax.device_count())
        assert mesh is not None and mesh.axis_names == ("data",)


def test_scheduler_under_explicit_mesh(served):
    """A 1-device ('data',) mesh context must not change the streams."""
    cfg, model, params = served
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    queue = synth_requests(
        3, prompt_len=PROMPT, gen=GEN, vocab_size=cfg.vocab_size, seed=5
    )
    plain = continuous_serve_loop(
        model, params, queue, batch_size=1, prompt_len=PROMPT, max_new=GEN, warmup=False
    )
    sharded = continuous_serve_loop(
        model, params, queue, batch_size=1, prompt_len=PROMPT, max_new=GEN,
        mesh=mesh, warmup=False,
    )
    for r in queue:
        np.testing.assert_array_equal(plain.outputs[r.id], sharded.outputs[r.id])
