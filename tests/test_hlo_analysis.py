"""The loop-aware HLO analyzer must count scan-body work trip-count times
(XLA's own cost_analysis counts it once — the bug this module exists for)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo, type_bytes


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_type_bytes():
    assert type_bytes("f32[16,64]{1,0}") == 16 * 64 * 4
    assert type_bytes("bf16[2,3]") == 12
    assert type_bytes("(f32[4], s8[8])") == 24
    assert type_bytes("pred[]") == 1  # scalar: one element
    assert type_bytes("f32[]") == 4


def test_single_matmul_flops():
    m, k, n = 64, 128, 32
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    comp = _compile(lambda x, y: x @ y, a, b)
    ana = analyze_hlo(comp.as_text())
    assert ana.flops == 2 * m * k * n


def test_scan_multiplies_by_trip_count():
    L, d = 7, 32
    w = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((4, d), jnp.float32)

    def fn(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0].sum()

    comp = _compile(fn, w, x)
    ana = analyze_hlo(comp.as_text())
    assert ana.flops == L * 2 * 4 * d * d
    assert any(n == L for n in ana.trip_counts.values())
    # XLA's own analysis undercounts (documents why analyze_hlo exists)
    from repro.launch.hlo_analysis import xla_cost_dict

    xla = xla_cost_dict(comp)
    assert float(xla.get("flops", 0)) < ana.flops


def test_grad_scan_flops():
    L, d = 5, 16
    w = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((2, d), jnp.float32)

    def fn(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0].sum()

    comp = _compile(jax.grad(fn), w, x)
    ana = analyze_hlo(comp.as_text())
    # fwd (1 dot) + bwd (2 dots) per layer
    assert ana.flops == pytest.approx(3 * L * 2 * 2 * d * d, rel=0.01)


def test_bytes_scale_with_trip_count():
    d = 64

    def fn(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0].sum()

    sizes = {}
    for L in (2, 8):
        comp = _compile(fn, jax.ShapeDtypeStruct((L, d, d), jnp.float32),
                        jax.ShapeDtypeStruct((4, d), jnp.float32))
        sizes[L] = analyze_hlo(comp.as_text()).bytes
    # 4x the layers -> ~4x the traffic (stacked weights are read per-layer)
    assert 3.0 < sizes[8] / sizes[2] < 5.0
