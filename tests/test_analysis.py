"""Static kernel auditor: interval domain soundness, derived-bound
rediscovery (seqmul n <= 12, packed 2n <= 31), gather-bounds proofs,
VMEM budget validation, seeded-mutation detection, and the
resolve_t / dispatch certification gates."""

import jax.numpy as jnp
import pytest

from repro.analysis import audit, contracts, interp
from repro.analysis.domain import Interval, add, bit_or, mul, shift_left
from repro.analysis.spec import TraceSpec, ValueRange, sds
from repro.analysis.vmem import (
    VMEM_BUDGET_BYTES,
    TileBudgetError,
    tile_footprint,
    validate_tiles,
)
from repro.engine import config as engine_config


def _iv(lo, hi, int_valued=True):
    return Interval(float(lo), float(hi), int_valued=int_valued)


def _audit(spec, **kw):
    return audit.audit_kernel(spec, **kw)


def _gating(result, kind):
    return [f for f in result.findings if f.gating and f.kind == kind]


# ------------------------------------------------------------ the domain


class TestIntervalDomain:
    def test_mul_covers_sign_combinations(self):
        r = mul(_iv(-3, 5), _iv(-7, 2))
        assert (r.lo, r.hi) == (-35.0, 21.0)
        assert r.int_valued

    def test_add_and_shift(self):
        assert add(_iv(0, 10), _iv(5, 5)).hi == 15.0
        s = shift_left(_iv(0, 255), _iv(4, 4))
        assert (s.lo, s.hi) == (0.0, 255.0 * 16)

    def test_bit_or_envelope_is_tight_for_disjoint_fields(self):
        # lo | (msp << 11): the envelope must not double past the sum,
        # which is what lets the seqmul assembly land at exactly 2^24-1.
        r = bit_or(_iv(0, 2**11 - 1), _iv(0, 2**24 - 2**11))
        assert r.hi == 2**24 - 1.0

    def test_bit_or_envelope_pow2_cap(self):
        # same-width operands: |a|b| never needs more bits than the
        # wider operand, so 255|255 stays 255 (not 510).
        assert bit_or(_iv(0, 255), _iv(0, 255)).hi == 255.0

    def test_xor_lower_bound_is_zero(self):
        # xor can cancel equal operands; max(a.lo, b.lo) would be unsound.
        r = bit_or(_iv(8, 255), _iv(8, 255), is_xor=True)
        assert r.lo == 0.0

    def test_bit_or_negative_operand_falls_back_to_top(self):
        r = bit_or(_iv(-1, 255), _iv(0, 255))
        assert r.hi == float("inf")


# ---------------------------------------------- derived-bound rediscovery


class TestBoundRediscovery:
    def test_seqmul_n12_certifies_at_exact_f32_frontier(self):
        res = _audit(contracts.kernel_trace("seqmul_gemm", 12, 6),
                     family="kernel", mode="seqmul_gemm", n=12, t=6)
        assert res.certified, [f.message for f in res.findings]
        # the assembled product envelope is exactly 2^24 - 1: the bound
        # is *derived*, with no slack to spare.
        assert any(v == float(2**24 - 1) for v in res.facts.values())

    def test_seqmul_n13_rejected_statically(self):
        res = _audit(contracts.kernel_trace("seqmul_gemm", 13, 6),
                     family="kernel", mode="seqmul_gemm", n=13, t=6)
        assert not res.certified
        assert _gating(res, "exactness") or _gating(res, "trace-rejected")

    def test_packed_single_n15_certifies_n16_breaks_contract(self):
        ok = _audit(contracts.kernel_trace("packed_single", 15, 7),
                    family="elementwise", mode="packed_single", n=15, t=7)
        assert ok.certified, [f.message for f in ok.findings]
        bad = _audit(contracts.kernel_trace("packed_single", 16, 8),
                     family="elementwise", mode="packed_single", n=16, t=8)
        assert not bad.certified
        assert _gating(bad, "contract"), [f.message for f in bad.findings]

    def test_two_word_kernel_carries_n16(self):
        res = _audit(contracts.kernel_trace("packed_words", 16, 8),
                     family="elementwise", mode="packed_words", n=16, t=8)
        assert res.certified, [f.message for f in res.findings]


# --------------------------------------------------- seeded mutation checks


class TestSeededMutations:
    """Each mutation re-introduces a bug class the auditor exists to
    catch; every one must produce a gating finding."""

    def test_widened_carry_weight_overflows_f32_exactness(self):
        n, t = 12, 6
        lo_max = float(2 ** (n - 1) - 1)
        lsp_max = float(2**t - 1)
        msp_max = float(2 ** (n - t + 1) - 1)
        ranges = [
            ValueRange(0.0, lo_max, int_valued=True),
            ValueRange(0.0, lsp_max, int_valued=True),
            ValueRange(0.0, msp_max, int_valued=True),
        ]

        def assemble(weight):
            def fn(lo, s_lsp, s_msp):
                return lo + jnp.float32(weight) * (
                    s_lsp + jnp.float32(2.0**t) * s_msp)
            return fn

        args = [sds((8, 8), jnp.float32)] * 3
        good = _audit(TraceSpec(name="assembly", fn=assemble(2.0 ** (n - 1)),
                                args=args, ranges=ranges))
        assert good.certified
        # mutation: widen the carry weight 2^(n-1) -> 2^n; the assembled
        # product now exceeds the 2^24 exact-f32 frontier.
        bad = _audit(TraceSpec(name="assembly-widened", fn=assemble(2.0**n),
                               args=args, ranges=ranges))
        assert not bad.certified
        assert _gating(bad, "exactness")

    def test_dropped_gather_clamp_is_caught(self):
        table = jnp.zeros((256,), jnp.float32)
        idx_range = [ValueRange(0.0, 256.0, int_valued=True)]  # one past end
        args = [sds((16,), jnp.int32)]

        clamped = _audit(TraceSpec(
            name="gather-clamped",
            fn=lambda idx: table[jnp.clip(idx, 0, 255)],
            args=args, ranges=idx_range))
        assert clamped.certified
        # mutation: drop the clamp; the index envelope now leaves the table.
        unclamped = _audit(TraceSpec(
            name="gather-unclamped", fn=lambda idx: table[idx],
            args=args, ranges=idx_range))
        assert not unclamped.certified
        assert _gating(unclamped, "gather")

    def test_oversized_tile_rejected_by_budget(self):
        with pytest.raises(TileBudgetError) as ei:
            validate_tiles("seqmul", 8, 4, (256, 256, 256))
        msg = str(ei.value)
        assert "seqmul" in msg and "n=8" in msg

    def test_non_power_of_two_tile_rejected(self):
        with pytest.raises(TileBudgetError) as ei:
            validate_tiles("seqmul", 8, 4, (48, 32, 32))
        assert "power" in str(ei.value)


# ------------------------------------------------------------ VMEM model


class TestVmemModel:
    def test_deployed_tiles_fit_for_every_mode(self):
        for mode in ("seqmul", "bitexact", "lowrank", "inject"):
            tiles = engine_config.kernel_tiles(mode, 8, 4)
            rep = tile_footprint(mode, 8, 4, (tiles.bm, tiles.bn, tiles.bk))
            assert rep.within_budget, (mode, rep.total_bytes)

    def test_footprint_monotone_in_tiles(self):
        small = tile_footprint("seqmul", 8, 4, (32, 32, 32))
        large = tile_footprint("seqmul", 8, 4, (64, 64, 64))
        assert small.total_bytes < large.total_bytes <= VMEM_BUDGET_BYTES * 8

    def test_traced_attention_vmem_within_budget(self):
        res = _audit(contracts.attention_trace("bitexact", 8, 2),
                     family="attention", mode="bitexact", n=8, t=2)
        assert res.certified, [f.message for f in res.findings]
        assert res.vmem and all(e["within_budget"] for e in res.vmem)


# ------------------------------------------------------- matrix & gating


class TestMatrixAndGates:
    def test_full_matrix_has_zero_unproven_kernels(self):
        results = audit.audit_matrix()
        bad = [(r.name, [f.message for f in r.findings])
               for r in results if not r.certified]
        assert not bad, bad
        assert len(results) >= 20

    def test_resolve_t_cannot_return_uncertified(self, monkeypatch):
        budget = engine_config.get_tier("balanced").budgets[0][1]
        p = engine_config.resolve_t(8, budget, mode="seqmul")
        assert audit.certified("seqmul", 8, p.t)
        # force every verdict negative: resolve_t must refuse rather
        # than hand out an unproven (n, t).
        monkeypatch.setattr(audit, "certified", lambda *a, **k: False)
        with pytest.raises(engine_config.QualityError, match="certification"):
            engine_config.resolve_t(8, budget, mode="seqmul")

    def test_dispatch_gate_refuses_uncertified_pallas(self, monkeypatch):
        import numpy as np

        from repro.engine import dispatch

        monkeypatch.setenv("REPRO_STATIC_AUDIT", "1")
        monkeypatch.setattr(audit, "certified", lambda *a, **k: False)
        x = jnp.asarray(np.ones((8, 8), np.float32))
        with pytest.raises(audit.CertificationError, match="seqmul"):
            dispatch.matmul(x, x, n=8, t=4, mode="seqmul", backend="pallas")
        # reference backend never goes through the gate
        dispatch.matmul(x, x, n=8, t=4, mode="seqmul", backend="reference")

    def test_contract_findings_are_gating(self):
        assert "contract" in interp.GATING_KINDS
        assert "note" not in interp.GATING_KINDS

    def test_report_is_machine_readable(self):
        rep = audit.report()
        assert rep["all_certified"] is True
        assert rep["vmem_budget_bytes"] == VMEM_BUDGET_BYTES
        entry = rep["entries"][0]
        assert {"name", "family", "certified", "findings", "vmem"} <= set(entry)
