"""End-to-end: loss decreases on structured data; approximate-multiplier
training runs; encoder-decoder trains; grad-accum equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import apply_approx, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.registry import build_model
from repro.train.steps import init_train_state, make_train_step


def _train(cfg, steps=60, batch=8, seq=64, tcfg=None, seed=0):
    m = build_model(cfg)
    tcfg = tcfg or TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=steps)
    state = init_train_state(m, tcfg, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(m, tcfg))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                  global_batch=batch, seed=seed))
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        if cfg.is_encdec:
            b["src_embeds"] = jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(7), i),
                (batch, seq, cfg.d_model), jnp.float32)
        state, metrics = step(state, b)
        losses.append(float(metrics["loss"]))
    return losses


def test_loss_decreases_dense():
    cfg = get_config("qwen3-0.6b").reduced(vocab_size=128)
    losses = _train(cfg)
    assert np.mean(losses[-10:]) < np.mean(losses[:5]) - 0.2


def test_loss_decreases_with_paper_technique():
    """Training *through* the approximate multiplier (inject mode) must
    still converge — the claim that lets the technique deploy at scale."""
    cfg = apply_approx(get_config("qwen3-0.6b").reduced(vocab_size=128),
                       mode="inject", n=8, t=4)
    losses = _train(cfg)
    assert np.mean(losses[-10:]) < np.mean(losses[:5]) - 0.2


def test_loss_decreases_encdec():
    cfg = get_config("seamless-m4t-large-v2").reduced(vocab_size=128)
    losses = _train(cfg, steps=40)
    assert np.mean(losses[-8:]) < np.mean(losses[:5]) - 0.1


def test_grad_accum_matches_single_batch():
    """grad_accum=4 over the same data must match accum=1 closely."""
    cfg = get_config("qwen3-0.6b").reduced(vocab_size=64, num_layers=2)
    m = build_model(cfg)
    data = SyntheticLM(DataConfig(vocab_size=64, seq_len=32, global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

    outs = {}
    for accum in (1, 4):
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0, total_steps=4,
                           grad_accum=accum)
        state = init_train_state(m, tcfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(m, tcfg))
        new_state, metrics = step(state, batch)
        outs[accum] = (
            np.asarray(jax.tree_util.tree_leaves(new_state.params)[0], np.float32),
            float(metrics["loss"]),
        )
    # losses may be averaged differently across microbatches; params must agree
    np.testing.assert_allclose(outs[1][0], outs[4][0], rtol=2e-2, atol=2e-4)
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-2)


def test_rng_per_step_differs():
    """Error-injection noise must differ across steps (rng folding)."""
    cfg = apply_approx(get_config("qwen3-0.6b").reduced(vocab_size=64, num_layers=2),
                       mode="inject")
    m = build_model(cfg)
    tcfg = TrainConfig(total_steps=4)
    state = init_train_state(m, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, tcfg))
    data = SyntheticLM(DataConfig(vocab_size=64, seq_len=16, global_batch=2))
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    s1, m1 = step(state, b)
    s2, m2 = step(s1, b)  # same batch, different step -> different noise
    assert float(m1["loss"]) != float(m2["loss"])
