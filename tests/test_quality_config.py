"""Accuracy-configuration subsystem (repro.engine.config): the (n, t)
controller, quality tiers, and the hardened estimator contracts.

Direction note (pinned here so nobody "fixes" it backwards): in the
paper's segmented design a *larger* t defers a *heavier* carry (weight
2^t), so the error-magnitude metrics grow with t — Eq. 11's MAE and the
closed-form NMED estimate are strictly increasing in t, and the
measured ER is non-monotone (it does decrease on the tail toward
t = n-1, but rises first).  This is the opposite of truncation-style
approximate multipliers where widening the exact LSP reduces error.
The controller therefore treats a budget as selecting the lower
interval [1, t_max] of valid splits and returns the cheapest by cycle
delay, ties toward the more accurate (smaller) split.
"""

import numpy as np
import pytest

from repro.core import error_metrics, error_model
from repro.engine import config as engine_config
from repro.engine.config import ErrorBudget, QualityError


# ------------------------------------------------------------- estimator
@pytest.mark.parametrize("n", [4, 8, 16])
def test_closed_form_metrics_monotone_in_t(n):
    """The controller's budget scale: NMED estimate and Eq. 11 MAE grow
    strictly with t — each budget therefore selects a unique t_max."""
    points = engine_config.sweep_t(n)
    assert [p.t for p in points] == list(range(1, n))
    for a, b in zip(points, points[1:]):
        assert a.nmed_est < b.nmed_est
        assert a.mae < b.mae
        assert 0.0 < a.er_bound <= 1.0


@pytest.mark.parametrize("n,t", [(4, 1), (4, 3), (6, 3), (8, 2), (8, 7)])
def test_er_bound_upper_bounds_measured_er(n, t):
    """er_msp is an *upper* estimate: a budget met in closed form is met
    by the exhaustively measured design."""
    est = error_model.estimate(n, t)
    rep = error_metrics.exhaustive_eval(n, t, fix_to_1=True)
    assert rep.er <= est.er_msp


def test_er_msp_decreases_on_the_tail():
    """The measured/estimated ER does fall off toward t = n-1 (the MSP
    shrinks, fewer cycles can observe the deferral) — the tail of the
    non-monotone ER curve, not a global monotonicity."""
    points = engine_config.sweep_t(8)
    ers = [p.er_bound for p in points]
    peak = ers.index(max(ers))
    assert all(x >= y for x, y in zip(ers[peak:], ers[peak + 1:]))


# ------------------------------------------------------------ controller
@pytest.mark.parametrize("n", [4, 8, 16])
def test_controller_returns_cheapest_valid_t(n):
    """Brute-force cross-check: for a ladder of budgets, resolve_t returns
    exactly min over the valid set by (cycle_delay, t) — and since the
    NMED scale is strictly increasing, for budgets binding at or below
    the delay-optimal split that is the unique cheapest (maximal) valid
    t, i.e. the minimal-delay t whose closed-form bound meets the
    target."""
    points = engine_config.sweep_t(n)
    for cut in points:
        budget = ErrorBudget(max_nmed=cut.nmed_est)
        valid = [p for p in points if p.nmed_est <= cut.nmed_est]
        expect = min(valid, key=lambda p: (p.delay, p.t))
        got = engine_config.resolve_t(n, budget)
        assert got.t == expect.t
        assert got.nmed_est <= cut.nmed_est  # the bound is actually met
        if max(p.t for p in valid) <= n // 2:
            # budget binds at/below the delay-optimal split: the unique
            # cheapest valid split is the maximal one
            assert got.t == max(p.t for p in valid)


def test_controller_tight_budget_returns_t1_and_impossible_raises():
    assert engine_config.resolve_t(8, ErrorBudget(max_nmed=5e-4)).t == 1
    with pytest.raises(QualityError):
        engine_config.resolve_t(8, ErrorBudget(max_nmed=1e-9))
    with pytest.raises(QualityError):
        engine_config.resolve_t(8, ErrorBudget(max_er=1e-6))


def test_controller_mae_budget():
    """An Eq. 11 budget behaves like the NMED one (same monotone scale)."""
    got = engine_config.resolve_t(8, ErrorBudget(max_mae=error_model.mae_closed_form(8, 3)))
    assert got.t == 3  # t=4 would be cheaper but violates the MAE budget


def test_default_t_is_the_derived_legacy_default():
    """The historical hardcoded n=8, t=4 is now the balanced tier's
    controller resolution."""
    assert engine_config.default_t(8) == 4
    from repro.configs.base import ApproxConfig

    ap = ApproxConfig()
    assert (ap.n, ap.t) == (engine_config.DEFAULT_N, engine_config.default_t(8))


def test_measured_marginals_shift_the_resolution():
    """Low-activity operands (paper: measured input PDFs) defer fewer
    carries, so the same budget affords a larger (cheaper) split."""
    budget = ErrorBudget(max_nmed=2e-3)
    uniform = engine_config.resolve_t(8, budget)
    quiet = engine_config.resolve_t(
        8, budget, pa=np.full(8, 0.1), pb=np.full(8, 0.1)
    )
    assert quiet.t >= uniform.t


# ----------------------------------------------------------------- tiers
def test_tier_registry_and_resolutions():
    tiers = engine_config.list_tiers()
    for name in ("exact", "high", "balanced", "draft"):
        assert name in tiers
    balanced = engine_config.resolve_tier("balanced")
    by_target = {q.target: q for q in balanced.per_target}
    assert by_target["mlp"].t == 4  # the derived legacy default
    assert by_target["attn"].t < by_target["mlp"].t  # attention is tighter
    high = engine_config.resolve_tier("high")
    for q in high.per_target:
        assert q.t <= by_target[q.target].t  # higher quality, smaller splits
    with pytest.raises(ValueError, match="unknown quality tier"):
        engine_config.get_tier("ultra-mega")


def test_apply_quality_installs_per_target_overrides():
    from repro.configs.registry import apply_quality, get_config

    cfg = apply_quality(get_config("qwen3-0.6b").reduced(), "balanced")
    ap = cfg.approx
    assert ap.enabled and ap.mode == "bitexact"
    assert set(ap.targets) == {"mlp", "attn", "moe"}
    assert ap.for_target("mlp").t == 4
    assert ap.for_target("attn").t == 2
    # resolved override carries no further overrides (no recursion)
    assert ap.for_target("attn").overrides == ()
    # a kind with no override inherits the base config unchanged
    assert ap.for_target("head") == ap
    exact = apply_quality(cfg, "exact")
    assert not exact.approx.enabled


def test_engine_matmul_defaults_resolve_via_controller():
    import jax.numpy as jnp

    from repro import engine

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    default = engine.matmul(x, w, mode="bitexact")
    explicit = engine.matmul(x, w, n=8, t=4, mode="bitexact")
    np.testing.assert_array_equal(np.asarray(default), np.asarray(explicit))
    a = jnp.asarray([3, 5], jnp.uint32)
    b = jnp.asarray([7, 11], jnp.uint32)
    np.testing.assert_array_equal(
        np.asarray(engine.multiply(a, b)),
        np.asarray(engine.multiply(a, b, n=8, t=4)),
    )


# ------------------------------------------------- speculative estimates
def _pinned_tier(t: int, n: int = 8) -> engine_config.QualityTier:
    """An on-the-fly tier whose mlp budget resolves to exactly ``t``.

    The NMED estimate is strictly increasing in t and cycle delay falls
    toward the delay-optimal split, so a budget of ``nmed_est(t)``
    admits [1, t] and the controller picks t itself (for t at or below
    the delay-optimal split — pinned by the assertion in the test).
    """
    pts = engine_config.sweep_t(n)
    return engine_config.QualityTier(
        name=f"pin{n}-{t}", mode="bitexact",
        budgets=(("mlp", ErrorBudget(max_nmed=pts[t - 1].nmed_est)),),
    )


def test_accept_rate_estimate_degenerate_pairs():
    """Same resolved quality on both sides: the verifier recomputes the
    draft exactly, so the estimate is exactly 1.0 — including the
    exact/exact pair (no budgets at all)."""
    for tier in engine_config.list_tiers():
        assert engine_config.accept_rate_estimate(tier, tier) == 1.0
    # distinct resolutions must not claim certainty
    for draft in ("high", "balanced", "draft"):
        est = engine_config.accept_rate_estimate(draft, "exact")
        assert 0.0 <= est < 1.0


def test_accept_rate_estimate_monotone_in_t():
    """A sloppier draft split (larger t, before the ER tail) can only
    lower the agreement estimate against an exact verifier."""
    ts = [1, 2, 3, 4]
    ers = [engine_config.sweep_t(8)[t - 1].er_bound for t in ts]
    assert ers == sorted(ers), "premise: ER bound rises toward the peak"
    ests = []
    for t in ts:
        tier = _pinned_tier(t)
        assert engine_config.resolve_tier(tier).per_target[0].t == t
        est = engine_config.accept_rate_estimate(tier, "exact")
        assert est == pytest.approx(max(0.0, 1.0 - ers[t - 1]))
        ests.append(est)
    assert all(a >= b for a, b in zip(ests, ests[1:]))


@pytest.mark.parametrize("td,tv", [(2, 1), (1, 2), (2, 2)])
def test_accept_rate_estimate_bounds_simulated_agreement(td, tv):
    """Exhaustive 4-bit check: the estimate is a true *lower* bound on
    the measured draft/verify agreement rate, and it is not slack by
    more than the union-bound gap (the two ER terms)."""
    from repro.engine import dispatch

    import jax.numpy as jnp

    n = 4
    pts = engine_config.sweep_t(n)
    draft, verify = _pinned_tier(td, n), _pinned_tier(tv, n)
    assert engine_config.resolve_tier(draft, n=n).per_target[0].t == td
    assert engine_config.resolve_tier(verify, n=n).per_target[0].t == tv
    est = engine_config.accept_rate_estimate(draft, verify, n=n)
    a, b = np.meshgrid(np.arange(2**n), np.arange(2**n))
    a = jnp.asarray(a.ravel(), jnp.uint32)
    b = jnp.asarray(b.ravel(), jnp.uint32)
    prod_d = np.asarray(dispatch.multiply(a, b, n=n, t=td, approx=True))
    prod_v = np.asarray(dispatch.multiply(a, b, n=n, t=tv, approx=True))
    measured = float(np.mean(prod_d == prod_v))
    assert measured >= est, (measured, est)
    gap = pts[td - 1].er_bound + pts[tv - 1].er_bound
    assert measured - est <= gap + 1e-12
    if td == tv:
        assert est == 1.0 and measured == 1.0


def test_expected_round_tokens_and_gain():
    """Round-economics sanity: the truncated-geometric mean and the
    break-even gate behave at the edges."""
    ert = engine_config.expected_round_tokens
    assert ert(0.0, 4) == 1.0  # nothing accepted: the verify token only
    assert ert(1.0, 4) == 5.0  # everything accepted: k + 1
    rates = [ert(a, 4) for a in (0.1, 0.3, 0.5, 0.9)]
    assert rates == sorted(rates) and all(1.0 < r < 5.0 for r in rates)
    with pytest.raises(ValueError):
        ert(-0.1, 4)
    with pytest.raises(ValueError):
        ert(0.5, 0)
    # a degenerate pair accepts everything at equal step cost: gain 1.0
    assert engine_config.speculation_gain("exact", "exact", 3) == pytest.approx(1.0)
    # the honest finding this layer surfaced: under the gate-delay cost
    # model a draft step still costs 0.55x an exact step, so no
    # registered pair clears break-even — SLOAdaptive declines to
    # speculate on real ladders (docs/serving.md records this)
    for draft in ("high", "balanced", "draft"):
        k, gain = engine_config.best_spec_k(draft, "exact")
        assert 1 <= k <= 8
        assert gain <= 1.0
        assert gain == pytest.approx(
            engine_config.speculation_gain(draft, "exact", k)
        )
