"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU; output shapes + finiteness.  (Deliverable f.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import apply_approx, get_config, list_archs
from repro.models.registry import build_model
from repro.train.steps import init_train_state, make_train_step

ARCHS = list_archs(include_paper=True)
B, S = 2, 16


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.is_encdec:
        b["src_embeds"] = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    ctx = m.ctx(jax.random.PRNGKey(1))
    kw = {}
    pos = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    if cfg.use_mrope:
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    if cfg.is_encdec:
        kw["src_embeds"] = batch["src_embeds"]
        kw["src_pos"] = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    hidden, _, aux = m.forward(params, batch["tokens"], pos, ctx, **kw)
    assert hidden.shape == (B, S, cfg.d_model)
    logits = m.lm_head(params, hidden)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    tcfg = TrainConfig(total_steps=10)
    state = init_train_state(m, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, tcfg))
    new_state, metrics = step(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state.step) == 1
    # params actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) > 0
        for a, b in zip(
            jax.tree_util.tree_leaves(state.params),
            jax.tree_util.tree_leaves(new_state.params),
        )
    )
    assert moved


@pytest.mark.parametrize("mode", ["fakequant", "inject", "lowrank", "bitexact"])
def test_approx_modes_train_step(mode):
    """The paper's technique deployed in each execution mode still trains."""
    cfg = apply_approx(get_config("qwen3-0.6b").reduced(), mode=mode, n=8, t=4)
    m = build_model(cfg)
    tcfg = TrainConfig(total_steps=10)
    state = init_train_state(m, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, tcfg))
    _, metrics = step(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))


def test_approx_changes_forward():
    """Enabling the segmented-carry-chain multiplier must change outputs."""
    base = get_config("qwen3-0.6b").reduced()
    cfg_a = apply_approx(base, mode="bitexact", n=6, t=2)
    key = jax.random.PRNGKey(0)
    m0, m1 = build_model(base), build_model(cfg_a)
    params = m0.init_params(key)
    pos = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
    tok = _batch(base)["tokens"]
    h0, _, _ = m0.forward(params, tok, pos, m0.ctx())
    h1, _, _ = m1.forward(params, tok, pos, m1.ctx())
    assert float(jnp.abs(h0 - h1).max()) > 0
