"""Pallas kernels vs. pure-jnp oracles (interpret=True on CPU), with
shape / bit-width / splitting-point sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import artifacts
from repro.kernels import ops, ref
from repro.kernels.lowrank_matmul import lowrank_matmul_pallas
from repro.kernels.lut_matmul import lut_matmul_pallas
from repro.kernels.seqmul_kernel import seqmul_pallas


@pytest.mark.parametrize("shape", [(8, 128), (16, 256), (128, 128), (3, 100)])
@pytest.mark.parametrize("n,t", [(8, 4), (8, 2), (6, 3), (4, 1), (15, 7)])
def test_seqmul_kernel_sweep(shape, n, t):
    rng = np.random.default_rng(n * 100 + t)
    a = jnp.asarray(rng.integers(0, 1 << n, size=shape), jnp.uint32)
    b = jnp.asarray(rng.integers(0, 1 << n, size=shape), jnp.uint32)
    for approx in (True, False):
        got = seqmul_pallas(a, b, n=n, t=t, approx=approx, interpret=True)
        want = ref.seqmul_ref(a, b, n=n, t=t, approx=approx)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("fix", [True, False])
def test_seqmul_kernel_fix_to_1(fix):
    n, t = 8, 4
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.integers(0, 1 << n, size=(64, 64)), jnp.uint32)
    b = jnp.asarray(rng.integers(0, 1 << n, size=(64, 64)), jnp.uint32)
    got = seqmul_pallas(a, b, n=n, t=t, approx=True, fix_to_1=fix, interpret=True)
    want = ref.seqmul_ref(a, b, n=n, t=t, approx=True, fix_to_1=fix)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("m,k,nn", [(16, 32, 16), (64, 64, 64), (128, 96, 32)])
@pytest.mark.parametrize("n,t", [(8, 4), (6, 2)])
def test_lut_matmul_kernel_sweep(m, k, nn, n, t):
    rng = np.random.default_rng(m + k + n)
    ma = jnp.asarray(rng.integers(0, 1 << n, size=(m, k)), jnp.uint32)
    mb = jnp.asarray(rng.integers(0, 1 << n, size=(k, nn)), jnp.uint32)
    sa = jnp.asarray(rng.choice([-1.0, 1.0], size=(m, k)), jnp.float32)
    sb = jnp.asarray(rng.choice([-1.0, 1.0], size=(k, nn)), jnp.float32)
    lut = artifacts.product_lut_flat(n, t, True)
    got = lut_matmul_pallas(lut, ma, sa, mb, sb, n=n, interpret=True)
    want = ref.lut_matmul_ref(ma, sa.astype(jnp.int8), mb, sb.astype(jnp.int8), n=n, t=t)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("m,k,nn,r", [(16, 32, 16, 4), (64, 48, 32, 8)])
def test_lowrank_matmul_kernel_sweep(m, k, nn, r):
    rng = np.random.default_rng(m * r)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, nn)), jnp.float32)
    ue = jnp.asarray(rng.standard_normal((m, k, r)), jnp.float32)
    ve = jnp.asarray(rng.standard_normal((k, nn, r)), jnp.float32)
    got = lowrank_matmul_pallas(a, b, ue, ve, rank=r, interpret=True)
    want = ref.lowrank_matmul_ref(a, b, ue, ve)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_ops_approx_multiply():
    n, t = 8, 4
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.integers(0, 1 << n, size=(32, 128)), jnp.uint32)
    b = jnp.asarray(rng.integers(0, 1 << n, size=(32, 128)), jnp.uint32)
    got = ops.approx_multiply(a, b, n=n, t=t)
    want = ref.seqmul_ref(a, b, n=n, t=t)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mode", ["bitexact", "lowrank"])
def test_ops_matmul_kernel_vs_core(mode):
    """The kernel-backed public GEMM must match core.approx_matmul."""
    from repro.core.approx_matmul import approx_matmul

    n, t = 8, 4
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
    got = ops.approx_matmul_kernel(x, w, n=n, t=t, mode=mode, rank=8)
    want = approx_matmul(x, w, n=n, t=t, mode=mode, rank=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
