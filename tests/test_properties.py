"""Hypothesis property tests on the multiplier's invariants.

``hypothesis`` is an optional test dependency (requirements-test.txt);
the module skips cleanly when it is absent so tier-1 collection never
hard-errors.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import boolean_ref, error_model, seqmul

_nt = st.integers(2, 12).flatmap(
    lambda n: st.tuples(st.just(n), st.integers(1, n - 1))
)


@settings(max_examples=60, deadline=None)
@given(_nt, st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
def test_exact_always_correct(nt, a, b):
    n, t = nt
    a, b = a % (1 << n), b % (1 << n)
    w = seqmul.seq_mul_words(np.uint32(a), np.uint32(b), n=n, t=t, approx=False)
    assert int(seqmul.assemble_product_u64(w, n=n, t=t)) == a * b


@settings(max_examples=60, deadline=None)
@given(_nt, st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1),
       st.booleans())
def test_ed_bounds(nt, a, b, fix):
    """|ED| never exceeds the closed-form worst cases of either sign."""
    n, t = nt
    a, b = a % (1 << n), b % (1 << n)
    w = seqmul.seq_mul_words(np.uint32(a), np.uint32(b), n=n, t=t,
                             approx=True, fix_to_1=fix)
    ed = a * b - int(seqmul.assemble_product_u64(w, n=n, t=t))
    assert ed <= error_model.max_ed_dropped_carry(n, t)
    assert -ed <= error_model.mae_closed_form(n, t) + (
        # fix-to-1 may overshoot up to the fixed pattern value
        (1 << (n + t)) if fix else 0
    )


@settings(max_examples=40, deadline=None)
@given(_nt, st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
def test_matches_boolean_reference(nt, a, b):
    n, t = nt
    a, b = a % (1 << n), b % (1 << n)
    w = seqmul.seq_mul_words(np.uint32(a), np.uint32(b), n=n, t=t, approx=True)
    got = int(seqmul.assemble_product_u64(w, n=n, t=t))
    ref = int(boolean_ref.int_from_bits(boolean_ref.mul_approx_bits(
        boolean_ref.bits_from_int(np.uint64(a), n)[None],
        boolean_ref.bits_from_int(np.uint64(b), n)[None], t=t))[0])
    assert got == ref


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 12), st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
def test_zero_and_identity(n, a, b):
    """x*0 == 0 and small operands are exact for every splitting point."""
    a = a % (1 << n)
    for t in range(1, n):
        w = seqmul.seq_mul_words(np.uint32(a), np.uint32(0), n=n, t=t, approx=True)
        assert int(seqmul.assemble_product_u64(w, n=n, t=t)) == 0
        w = seqmul.seq_mul_words(np.uint32(1), np.uint32(a), n=n, t=t, approx=True)
        got = int(seqmul.assemble_product_u64(w, n=n, t=t))
        # multiplying by 1 generates no carries anywhere -> exact
        assert got == a


@settings(max_examples=8, deadline=None)
@given(st.integers(3, 8))
def test_med_monotone_in_t(n):
    """Mean |ED| grows with the splitting point t (exhaustive): deferred
    carries carry weight 2^t, which dominates their decreasing frequency.
    (Accuracy favors small t; t=n/2 is the *latency* optimum — the paper's
    accuracy-configurability axis.)"""
    from repro.core import error_metrics

    meds = [error_metrics.exhaustive_eval(n, t, fix_to_1=False).med_abs
            for t in range(1, n)]
    assert all(meds[i + 1] >= meds[i] for i in range(len(meds) - 1)), (n, meds)
