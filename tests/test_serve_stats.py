"""Unit tests for serve-side measurement primitives (repro.serve.stats).

The soak harness leans on ``percentile`` for window audits, so its edge
cases are pinned directly: an empty distribution is ``None`` (never a
sentinel 0.0 that reads as "instant"), a single sample is its own
percentile at every q, and an out-of-range q raises here instead of
deep inside numpy.  ``SlotAccounting``'s derived counters (leaks, reuse
spread) are pure arithmetic — pinned so audit semantics cannot drift.
"""

import pytest

from repro.serve.stats import ServeStats, SlotAccounting, fmt_ms, percentile


def test_percentile_empty_is_none():
    assert percentile((), 50) is None
    assert percentile([], 99.9) is None


def test_percentile_single_sample_is_itself():
    for q in (0.0, 50.0, 99.0, 99.9, 100.0):
        assert percentile([0.25], q) == pytest.approx(0.25)


def test_percentile_basic_median_and_tails():
    vals = [1.0, 2.0, 3.0, 4.0, 5.0]
    assert percentile(vals, 50) == pytest.approx(3.0)
    assert percentile(vals, 0) == pytest.approx(1.0)
    assert percentile(vals, 100) == pytest.approx(5.0)
    # generators are consumed once and still work
    assert percentile((v for v in vals), 50) == pytest.approx(3.0)


def test_percentile_rejects_out_of_range_q():
    with pytest.raises(ValueError, match=r"\[0, 100\]"):
        percentile([1.0], -1)
    with pytest.raises(ValueError, match=r"\[0, 100\]"):
        percentile([1.0], 100.5)
    # q validation applies to the empty case too (caller bug either way)
    with pytest.raises(ValueError, match=r"\[0, 100\]"):
        percentile([], 200)


def test_fmt_ms_consistent_with_percentile():
    assert fmt_ms((), 50) == "n/a"
    assert fmt_ms((0.1,), 50) == "100ms"
    assert fmt_ms((0.1, 0.3), 50) == "200ms"


def test_summary_renders_na_on_empty_ttft():
    empty = ServeStats(
        requests=0, tokens_out=0, wall_s=0.0, prefill_s=0.0, decode_s=0.0,
        batch_latencies_s=(), devices=1, scheduler="continuous",
    )
    assert "ttft p50 n/a" in empty.summary()
    assert "0ms" not in empty.summary()


def test_slot_accounting_derived_counters():
    clean = SlotAccounting(
        seated=12, retired=12, pool_prefill_seats=4, admission_seats=8,
        max_live=4, slot_reuse=(3, 3, 3, 3), position_violations=0,
    )
    assert clean.slot_leaks == 0
    assert clean.reuse_spread == 0

    leaky = SlotAccounting(
        seated=12, retired=10, pool_prefill_seats=4, admission_seats=8,
        max_live=4, slot_reuse=(5, 3, 2, 2), position_violations=1,
    )
    assert leaky.slot_leaks == 2
    assert leaky.reuse_spread == 3

    static = SlotAccounting(
        seated=7, retired=7, pool_prefill_seats=7, admission_seats=0,
        max_live=4, slot_reuse=(), position_violations=0,
    )
    assert static.slot_leaks == 0
    assert static.reuse_spread == 0
