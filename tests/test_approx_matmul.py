"""Approximate-GEMM modes (core.approx_matmul) against exact references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import luts, quantization
from repro.core.approx_matmul import approx_matmul, approx_matmul_int, error_moments


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


def test_bitexact_int_gemm_matches_lut_reduction():
    n, t = 6, 3
    rng = np.random.default_rng(0)
    ma = rng.integers(0, 1 << n, size=(8, 16), dtype=np.uint32)
    mb = rng.integers(0, 1 << n, size=(16, 12), dtype=np.uint32)
    sa = rng.choice([-1, 1], size=(8, 16)).astype(np.int8)
    sb = rng.choice([-1, 1], size=(16, 12)).astype(np.int8)
    got = np.asarray(approx_matmul_int(ma, sa, mb, sb, n=n, t=t))
    lut = luts.product_lut(n, t)
    want = np.zeros((8, 12))
    for i in range(8):
        for j in range(12):
            want[i, j] = sum(
                float(lut[ma[i, k], mb[k, j]]) * sa[i, k] * sb[k, j] for k in range(16)
            )
    np.testing.assert_allclose(got, want)


def test_mode_exact_is_matmul():
    x, w = _rand((16, 32), 0), _rand((32, 8), 1)
    np.testing.assert_allclose(
        np.asarray(approx_matmul(x, w, mode="exact")), np.asarray(x @ w), rtol=1e-6
    )


def test_bitexact_mode_close_to_quantized_exact():
    """bitexact == quantized exact GEMM + bounded approximate-product error."""
    n, t = 8, 4
    x, w = _rand((24, 64), 2), _rand((64, 16), 3)
    got = np.asarray(approx_matmul(x, w, n=n, t=t, mode="bitexact"))
    # reference: same quantization, exact products
    qx = quantization.calibrate_absmax(x, bits=n)
    qw = quantization.calibrate_absmax(w, bits=n)
    mx, sx = quantization.quantize(x, qx)
    mw, sw = quantization.quantize(w, qw)
    ax = np.asarray(mx, np.float64) * np.asarray(sx, np.float64)
    aw = np.asarray(mw, np.float64) * np.asarray(sw, np.float64)
    exact_q = (ax @ aw) * float(qx.scale * qw.scale)
    err_lut = luts.error_lut(n, t)
    bound = np.abs(err_lut).max() * 64 * float(qx.scale * qw.scale)
    assert np.abs(got - exact_q).max() <= bound
    # and it should usually differ from the exact path (errors do occur)
    assert np.abs(got - exact_q).max() > 0


def test_lowrank_mode_tracks_bitexact():
    n, t = 6, 3
    x, w = _rand((32, 48), 4), _rand((48, 24), 5)
    bitexact = np.asarray(approx_matmul(x, w, n=n, t=t, mode="bitexact"))
    exact = np.asarray(approx_matmul(x, w, n=n, t=t, mode="exact"))
    full = np.asarray(approx_matmul(x, w, n=n, t=t, mode="lowrank", rank=1 << n))
    r8 = np.asarray(approx_matmul(x, w, n=n, t=t, mode="lowrank", rank=8))
    # full-rank correction reproduces the bit-exact semantics
    np.testing.assert_allclose(full, bitexact, rtol=1e-4, atol=1e-4)
    # rank-8 must be closer to bitexact than the uncorrected exact GEMM
    assert np.abs(r8 - bitexact).mean() < np.abs(exact - bitexact).mean()


def test_inject_mode_moments():
    n, t = 8, 4
    mean, std = error_moments(n, t)
    x, w = _rand((64, 128), 6), _rand((128, 32), 7)
    outs = []
    for s in range(8):
        out = approx_matmul(x, w, n=n, t=t, mode="inject", key=jax.random.PRNGKey(s))
        outs.append(np.asarray(out))
    exact = np.asarray(x @ w)
    spread = np.std(np.stack(outs), axis=0).mean()
    assert spread > 0  # stochastic
    # bias matches mean * K * scale within MC noise
    qx = quantization.calibrate_absmax(x, bits=n)
    qw = quantization.calibrate_absmax(w, bits=n)
    scale = float(qx.scale * qw.scale)
    expected_bias = mean * 128 * scale
    got_bias = (np.mean(np.stack(outs)) - exact.mean())
    assert got_bias == pytest.approx(expected_bias, abs=6 * std * np.sqrt(128.0) * scale / np.sqrt(64 * 32 * 8))


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        approx_matmul(_rand((4, 4)), _rand((4, 4)), mode="nope")
    with pytest.raises(ValueError):
        approx_matmul(_rand((4, 4)), _rand((4, 4)), mode="inject")  # needs key
