"""Workload generator + soak harness correctness (repro.serve.{workload,soak}).

Four layers of guarantees, reduced-size versions of what the ``soak-smoke``
CI job and the ``serve_soak`` suite run at scale:

* **Invariant sweep** — every workload preset × tier mix streams through
  the continuous scheduler with zero slot leaks, zero lost/duplicate
  serves, zero per-row write-position violations, and passing parity
  spot-checks (sampled requests re-served alone, unpadded, bit-match).
* **Deterministic replay** — one (spec, seed) pair fully determines the
  request trace (byte-identical, pinned by ``trace_digest``) *and* the
  scheduler's retirement order, so any red soak reproduces from the
  seed recorded in ``BENCH_serve_soak.json``.
* **Falsifiability** — the audit actually fires: a fabricated lost
  request and an over-tight drift limit both turn the report red.
* **Adversarial edges** — zero-budget requests are rejected at
  construction, bucket-capacity prompts serve cleanly, a tier-mismatched
  request aborts at admission mid-stream, and a request retiring on its
  first decode step leaks no slot.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models.registry import build_model
from repro.serve import Request, synth_requests
from repro.serve.scheduler import continuous_serve_loop
from repro.serve.soak import _audit_window, run_soak
from repro.serve.workload import (
    PRESETS,
    WorkloadSpec,
    generate,
    iter_requests,
    iter_windows,
    preset_spec,
    tier_mix_label,
    trace_digest,
)

PROMPT, GEN = 8, 4
VOCAB = 64  # model-free workload tests only need a vocab bound


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen3-0.6b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _spec(cfg, preset, tier, requests=18):
    mix = () if tier is None else ((None, 1.0), (tier, 2.0))
    return preset_spec(preset, requests=requests, prompt_len=PROMPT, max_new=GEN,
                       vocab_size=cfg.vocab_size, tier_mix=mix)


# ------------------------------------------------------------ workload model
def test_workload_bounds_and_shapes():
    spec = WorkloadSpec(requests=400, prompt_len=PROMPT, max_new=GEN,
                        vocab_size=VOCAB, arrival="poisson", rate_rps=100.0,
                        prompt_dist="zipf", gen_dist="lognormal")
    w = generate(spec, seed=0)
    lens = np.array([r.prompt_len for r in w.requests])
    buds = np.array([r.max_new for r in w.requests])
    assert lens.min() >= 1 and lens.max() <= PROMPT
    assert buds.min() >= 1 and buds.max() <= GEN
    # zipf is long-tail: mostly short, but the tail reaches the bucket
    assert lens.mean() < (1 + PROMPT) / 2
    assert lens.max() == PROMPT
    arr = np.array(w.arrivals_s)
    assert np.all(np.diff(arr) >= 0), "arrival times must be nondecreasing"
    # poisson offered rate lands near the spec'd rate (loose: finite draw)
    assert w.offered_rps == pytest.approx(100.0, rel=0.5)


def test_bursty_arrivals_are_clumped():
    base = dict(requests=2000, prompt_len=PROMPT, max_new=GEN, vocab_size=VOCAB,
                rate_rps=64.0)
    poisson = generate(WorkloadSpec(arrival="poisson", **base), seed=0)
    bursty = generate(WorkloadSpec(arrival="bursty", burst_factor=16.0,
                                   burst_fraction=0.1, **base), seed=0)

    def cv(w):  # coefficient of variation of inter-arrival gaps
        gaps = np.diff(np.array(w.arrivals_s))
        return gaps.std() / gaps.mean()

    # exponential gaps have CV ~= 1; the MMPP must be visibly burstier
    assert cv(poisson) == pytest.approx(1.0, abs=0.25)
    assert cv(bursty) > cv(poisson) * 1.5


def test_abuse_presets():
    flood = preset_spec("flood", requests=30, prompt_len=PROMPT, max_new=GEN,
                        vocab_size=VOCAB)
    for r, t in iter_requests(flood, 0):
        assert r.prompt_len == PROMPT and r.max_new == GEN
        assert t == 0.0  # the whole flood is queued at once
    churn = preset_spec("churn", requests=30, prompt_len=PROMPT, max_new=GEN,
                        vocab_size=VOCAB)
    # churn budgets are zipf from 1: mostly instant-retire with a short
    # tail above it, and the preset asks the soak harness to probe a
    # real eos id so the tail retires by true EOS, not budget
    buds = [r.max_new for r, _ in iter_requests(churn, 0)]
    assert min(buds) == 1
    assert sum(1 for b in buds if b == 1) > len(buds) // 2
    assert churn.eos_probe and churn.eos_id is None


def test_tier_mix_assignment_and_label():
    spec = WorkloadSpec(requests=300, prompt_len=PROMPT, max_new=GEN,
                        vocab_size=VOCAB,
                        tier_mix=((None, 1.0), ("balanced", 3.0)))
    tags = [r.quality for r, _ in iter_requests(spec, 0)]
    n_tier = sum(1 for t in tags if t == "balanced")
    assert set(tags) == {None, "balanced"}
    assert 0.55 < n_tier / len(tags) < 0.95  # ~75% expected
    assert tier_mix_label(spec.tier_mix) == "none:1+balanced:3"
    assert tier_mix_label(()) == "none"


def test_iter_windows_is_bounded_and_ordered():
    spec = WorkloadSpec(requests=50, prompt_len=PROMPT, max_new=GEN,
                        vocab_size=VOCAB)
    seen = []
    for reqs, times in iter_windows(spec, seed=2, window_size=16):
        assert len(reqs) <= 16 and len(reqs) == len(times)
        seen.extend(r.id for r in reqs)
    assert seen == list(range(50))
    with pytest.raises(ValueError, match="window_size"):
        next(iter_windows(spec, 0, 0))


def test_spec_validation():
    base = dict(requests=4, prompt_len=PROMPT, max_new=GEN, vocab_size=VOCAB)
    with pytest.raises(ValueError, match="arrival"):
        WorkloadSpec(arrival="tidal", **base)
    with pytest.raises(ValueError, match="prompt_dist"):
        WorkloadSpec(prompt_dist="cauchy", **base)
    with pytest.raises(ValueError, match="zipf_a"):
        WorkloadSpec(zipf_a=1.0, **base)
    with pytest.raises(ValueError, match="burst_fraction"):
        WorkloadSpec(burst_fraction=1.0, **base)
    with pytest.raises(ValueError, match="weight"):
        WorkloadSpec(tier_mix=(("balanced", 0.0),), **base)
    with pytest.raises(ValueError, match="min_gen"):
        WorkloadSpec(min_gen=GEN + 1, **base)
    with pytest.raises(ValueError, match="unknown workload preset"):
        preset_spec("slashdot", **base)


def test_synth_requests_delegates_and_stays_byte_stable():
    # the legacy draw is pinned: committed BENCH baselines depend on the
    # same seed producing the same queue forever
    legacy = synth_requests(5, prompt_len=8, gen=6, vocab_size=50, seed=0)
    assert [(r.prompt_len, r.max_new) for r in legacy] == [
        (8, 4), (7, 6), (8, 2), (7, 6), (6, 3)
    ]
    # preset delegation: realistic mixes through the old entry point
    churn = synth_requests(8, prompt_len=8, gen=6, vocab_size=50, seed=0,
                           workload="churn")
    assert min(r.max_new for r in churn) == 1  # zipf-from-1 budgets
    tagged = synth_requests(8, prompt_len=8, gen=6, vocab_size=50, seed=0,
                            workload="steady", quality="balanced")
    assert all(r.quality == "balanced" for r in tagged)


# ------------------------------------------------------- deterministic replay
def test_trace_digest_replays_byte_identical():
    spec = preset_spec("bursty", requests=64, prompt_len=PROMPT, max_new=GEN,
                       vocab_size=VOCAB, tier_mix=((None, 1.0), ("high", 1.0)))
    assert trace_digest(spec, 7) == trace_digest(spec, 7)
    assert trace_digest(spec, 7) != trace_digest(spec, 8)
    a, b = generate(spec, 7), generate(spec, 7)
    assert a.arrivals_s == b.arrivals_s
    for ra, rb in zip(a.requests, b.requests):
        assert ra.id == rb.id and ra.max_new == rb.max_new
        assert ra.quality == rb.quality
        assert ra.tokens.tobytes() == rb.tokens.tobytes()


def test_soak_replay_identical_retirement_order(served):
    cfg, model, params = served
    spec = _spec(cfg, "bursty", None, requests=14)
    a = run_soak(model, params, spec, batch_size=2, seed=5, window_size=7)
    b = run_soak(model, params, spec, batch_size=2, seed=5, window_size=7)
    assert a.ok and b.ok
    assert a.retirement_order == b.retirement_order
    assert len(a.retirement_order) == spec.requests


# ------------------------------------------------------------ invariant sweep
@pytest.mark.parametrize("tier", [None, "balanced"])
@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_soak_invariants(served, preset, tier):
    cfg, model, params = served
    spec = _spec(cfg, preset, tier)
    report = run_soak(
        model, params, spec, batch_size=2, seed=3, window_size=6,
        quality=tier, spot_check=2,
    )
    assert report.ok, report.violations
    row = report.summary_row()
    assert row["seated"] == row["retired"] == spec.requests
    assert row["slot_leaks"] == 0
    assert row["lost_requests"] == 0
    assert row["duplicate_serves"] == 0
    assert row["position_violations"] == 0
    assert row["invariants_ok"] == 1.0
    if tier is None:
        assert report.spot_checks == 2 and report.spot_check_failures == 0
    else:
        # approx tiers have no cross-batch bit oracle (quantization
        # artifacts are batch-dependent); parity is pinned batch-for-batch
        # in test_serve_scheduler.py instead
        assert report.spot_checks == 0
    # every seat is attributed to a physical slot
    assert sum(report.slot_reuse) == spec.requests
    assert row["seed"] == 3  # failures must reproduce from the row alone


def test_churn_eos_probe_retires_rows_before_budget(served):
    """Regression for the churn preset's true-EOS path: the probed modal
    first token becomes the trace's eos_id, so tail rows (budget > 1)
    retire by *emitting EOS* before exhausting max_new — instant-EOS
    retirement exercised for real, not via the budget-1 stand-in."""
    from repro.serve.soak import probe_eos_id

    cfg, model, params = served
    spec = preset_spec("churn", requests=32, prompt_len=PROMPT, max_new=GEN,
                       vocab_size=cfg.vocab_size)
    assert spec.eos_probe and spec.eos_id is None
    eos = probe_eos_id(model, params, spec, seed=0)
    assert 0 <= eos < cfg.vocab_size
    w = generate(dataclasses.replace(spec, eos_id=eos, eos_probe=False), seed=0)
    result = continuous_serve_loop(
        model, params, list(w.requests), batch_size=2, prompt_len=PROMPT,
        max_new=GEN, warmup=False,
    )
    by_id = {r.id: r for r in w.requests}
    eos_rows = [rs for rs in result.request_stats if rs.finish_reason == "eos"]
    assert eos_rows, "probed eos id never fired"
    early = [rs for rs in eos_rows
             if len(result.outputs[rs.id]) < by_id[rs.id].max_new]
    assert early, "no row retired before its budget via EOS"
    for rs in early:
        assert result.outputs[rs.id][-1] == eos
    # the full soak path wires the probe in automatically and stays green
    report = run_soak(model, params, spec, batch_size=2, seed=0,
                      window_size=16, spot_check=0)
    assert report.ok, report.violations
    assert report.eos_retired > 0


def test_soak_static_baseline(served):
    cfg, model, params = served
    spec = _spec(cfg, "steady", None, requests=12)
    report = run_soak(model, params, spec, batch_size=2, seed=1, window_size=6,
                      scheduler="static", spot_check=2)
    assert report.ok, report.violations
    assert report.scheduler == "static"
    assert report.slot_reuse == ()  # no slot pool to account
    assert report.spot_checks == 0  # padded static streams have no unpadded oracle


# --------------------------------------------------------------- falsifiability
def test_audit_flags_fabricated_loss_and_duplicate(served):
    """The auditor itself must be falsifiable: feed it a doctored result."""
    cfg, model, params = served
    queue = synth_requests(4, prompt_len=PROMPT, gen=2, vocab_size=cfg.vocab_size,
                           seed=9)
    result = continuous_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, max_new=2,
        warmup=False,
    )
    # drop one output (a "lost" request) — the audit must notice
    doctored = dataclasses.replace(
        result, outputs={k: v for k, v in result.outputs.items() if k != queue[0].id}
    )
    audit = _audit_window(0, queue, [0.0] * len(queue), doctored, set())
    assert audit.lost_requests == 1
    assert any("lost" in v for v in audit.violations)
    # replaying the same ids in a later window is a duplicate serve
    served_ids = set(result.outputs)
    audit2 = _audit_window(1, queue, [0.0] * len(queue), result, served_ids)
    assert audit2.duplicate_serves == len(queue)
    assert any("twice" in v for v in audit2.violations)


def test_drift_gate_fires(served):
    cfg, model, params = served
    spec = _spec(cfg, "steady", None, requests=18)
    report = run_soak(model, params, spec, batch_size=2, seed=3, window_size=6,
                      drift_limit=1e-9)
    assert not report.ok
    assert any("drift" in v for v in report.violations)
    assert report.summary_row()["invariants_ok"] == 0.0


# ------------------------------------------------------------ adversarial edges
def test_zero_budget_request_rejected_at_construction():
    with pytest.raises(ValueError, match="max_new"):
        Request(id=0, tokens=np.zeros(4, np.int32), max_new=0)
    with pytest.raises(ValueError, match="min_gen"):
        WorkloadSpec(requests=1, prompt_len=PROMPT, max_new=GEN,
                     vocab_size=VOCAB, min_gen=0)


def test_prompt_at_bucket_capacity_serves_cleanly(served):
    """prompt_len == bucket: zero left pads, write slots to capacity-1."""
    cfg, model, params = served
    rng = np.random.default_rng(21)
    queue = [Request(id=i, tokens=rng.integers(0, cfg.vocab_size, PROMPT)
                     .astype(np.int32), max_new=GEN) for i in range(3)]
    result = continuous_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, max_new=GEN,
        warmup=False,
    )
    acct = result.accounting
    assert acct.slot_leaks == 0 and acct.position_violations == 0
    assert all(len(result.outputs[r.id]) == GEN for r in queue)


def test_tier_mismatch_rejected_at_admission_mid_stream(served):
    """A mismatched tier tag arriving mid-stream aborts at admission —
    never silently served at the pool's different accuracy."""
    cfg, model, params = served
    rng = np.random.default_rng(23)

    def req(i, quality=None):
        return Request(id=i, tokens=rng.integers(0, cfg.vocab_size, PROMPT)
                       .astype(np.int32), max_new=GEN, quality=quality)

    # batch 1: the tagged request is only reached after two full serves
    queue = [req(0), req(1), req(2, quality="high")]
    with pytest.raises(ValueError, match="serves 'balanced'"):
        continuous_serve_loop(
            model, params, queue, batch_size=1, prompt_len=PROMPT, max_new=GEN,
            warmup=False, quality="balanced",
        )


def test_first_step_retirement_leaks_no_slot(served):
    """Regression: budget-1 (retire at admission) and budget-2 (retire on
    the first decode step) must both free their slot for reuse."""
    cfg, model, params = served
    rng = np.random.default_rng(29)
    queue = [
        Request(id=0, tokens=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                max_new=2),
        Request(id=1, tokens=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                max_new=1),
        Request(id=2, tokens=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                max_new=2),
    ]
    result = continuous_serve_loop(
        model, params, queue, batch_size=1, prompt_len=PROMPT, max_new=GEN,
        warmup=False,
    )
    acct = result.accounting
    assert acct.seated == acct.retired == 3
    assert acct.slot_reuse == (3,)  # the single slot hosted every request
    assert acct.position_violations == 0
    assert [len(result.outputs[i]) for i in range(3)] == [2, 1, 2]
