"""Multi-device integration tests, run in subprocesses so the forced
host-device count never leaks into the rest of the suite."""

import os
import subprocess
import sys
import textwrap


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8) -> str:
    src = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", src], env=env, capture_output=True,
                         text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_sharded_matches_local():
    """shard_map expert-parallel dispatch == single-host path, bit-exact
    when capacity doesn't drop."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.models import moe
        from repro.models.layers import Ctx

        cfg = get_config('granite-moe-1b-a400m').reduced(
            num_experts=8, num_experts_per_tok=2, moe_d_ff=16, d_model=32,
            capacity_factor=8.0)
        ctx = Ctx(cfg=cfg)
        params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)
        out_ref, aux_ref = moe.moe_ffn(params, x, ctx)
        from repro.distributed.sharding import make_auto_mesh, mesh_context
        mesh = make_auto_mesh((2, 4), ("data", "model"))
        with mesh_context(mesh):
            out_sh, aux_sh = jax.jit(lambda p, v: moe.moe_ffn(p, v, ctx))(params, x)
        np.testing.assert_allclose(np.asarray(out_sh), np.asarray(out_ref),
                                   rtol=2e-4, atol=2e-5)
        assert abs(float(aux_sh) - float(aux_ref)) < 1e-4
    """)


def test_train_step_compiles_and_runs_on_mesh():
    """One real train step on a (2, 4) mesh with FSDP+TP shardings,
    vocab-sharded CE, grad accumulation — values finite and param
    update nonzero."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import TrainConfig
        from repro.configs.registry import get_config
        from repro.launch import specs as S
        from repro.models.registry import build_model
        from repro.train.steps import init_train_state, make_train_step

        cfg = get_config('qwen3-0.6b').reduced(
            num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
            d_ff=128, vocab_size=512)
        model = build_model(cfg)
        tcfg = TrainConfig(total_steps=4, grad_accum=2)
        from repro.distributed.sharding import make_auto_mesh, mesh_context
        mesh = make_auto_mesh((2, 4), ("data", "model"))
        with mesh_context(mesh):
            state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
            state_sh = S.state_shardings(jax.eval_shape(lambda: state), mesh)
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s) if hasattr(s, 'spec') else x,
                state, state_sh)
            step = jax.jit(make_train_step(model, tcfg), donate_argnums=0)
            batch = {
                'tokens': jnp.zeros((8, 32), jnp.int32),
                'labels': jnp.ones((8, 32), jnp.int32),
            }
            state1, metrics = step(state, batch)
            assert np.isfinite(float(metrics['loss'])), metrics
            state2, metrics2 = step(state1, batch)
            assert float(metrics2['loss']) != float(metrics['loss'])
    """)


def test_decode_on_mesh_with_sharded_caches():
    """Prefill + decode with the cache-sharding rules on a mesh."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import get_config
        from repro.models.registry import build_model
        from repro.train.steps import make_decode_step, make_prefill_step

        cfg = get_config('gemma2-9b').reduced(
            num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
            d_ff=128, vocab_size=256, local_window=8)
        model = build_model(cfg)
        from repro.distributed.sharding import make_auto_mesh, mesh_context
        mesh = make_auto_mesh((2, 4), ("data", "model"))
        with mesh_context(mesh):
            params = model.init_params(jax.random.PRNGKey(0))
            prefill = jax.jit(make_prefill_step(model, 16))
            decode = jax.jit(make_decode_step(model), donate_argnums=1)
            caches, logits = prefill(params, {'tokens': jnp.zeros((4, 8), jnp.int32)})
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            for i in range(3):
                logits, caches = decode(params, caches, tok, jnp.int32(8 + i))
            assert bool(jnp.isfinite(logits).all())
    """)


def test_hlo_collectives_visible_on_mesh():
    """The analyzer sees the TP collectives of a sharded matmul chain."""
    _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.hlo_analysis import analyze_hlo

        from repro.distributed.sharding import make_auto_mesh, mesh_context
        mesh = make_auto_mesh((2, 4), ("data", "model"))
        def f(x, w1, w2):
            h = jnp.tanh(x @ w1)
            return (h @ w2).sum()
        with mesh_context(mesh):
            comp = jax.jit(jax.grad(f), in_shardings=(
                NamedSharding(mesh, P("data", None)),
                NamedSharding(mesh, P(None, "model")),
                NamedSharding(mesh, P("model", None)),
            )).lower(
                jax.ShapeDtypeStruct((16, 64), jnp.float32),
                jax.ShapeDtypeStruct((64, 128), jnp.float32),
                jax.ShapeDtypeStruct((128, 64), jnp.float32),
            ).compile()
        ana = analyze_hlo(comp.as_text())
        assert ana.collective_total > 0, ana.collective_bytes
        assert ana.flops > 0
    """)
