"""Error metrics (Section III-B) and the probabilistic estimator (V-B)."""

import numpy as np
import pytest

from repro.core import boolean_ref, error_metrics, error_model


@pytest.mark.parametrize("n,t", [(4, 2), (6, 3), (8, 4), (8, 2)])
def test_exhaustive_report_consistency(n, t):
    rep = error_metrics.exhaustive_eval(n, t, fix_to_1=False)
    assert rep.samples == 1 << (2 * n)
    # Eq. 11 shows up as the most-negative ED (overshoot), exactly
    assert -rep.max_ed_neg == error_model.mae_closed_form(n, t)
    assert rep.mae >= rep.med_abs
    assert 0.0 <= rep.er <= 1.0
    assert rep.nmed == pytest.approx(rep.med_abs / (2**n - 1) ** 2)
    # BER of the always-exact LSBs (bits below t+1) is 0 without fix-to-1
    for i in range(min(t + 1, len(rep.ber))):
        assert rep.ber[i] == 0.0


@pytest.mark.parametrize("n,t", [(6, 3), (8, 4)])
def test_fix_to_1_reduces_med_abs(n, t):
    """The paper's motivation for the fix-to-1 multiplexers."""
    r_off = error_metrics.exhaustive_eval(n, t, fix_to_1=False)
    r_on = error_metrics.exhaustive_eval(n, t, fix_to_1=True)
    assert r_on.med_abs < r_off.med_abs


def test_mc_converges_to_exhaustive():
    n, t = 8, 4
    ex = error_metrics.exhaustive_eval(n, t)
    mc = error_metrics.mc_eval(n, t, samples=1 << 18, seed=3)
    assert mc.er == pytest.approx(ex.er, rel=0.05)
    assert mc.med_abs == pytest.approx(ex.med_abs, rel=0.1)


def test_mc_with_input_pdf():
    n, t = 6, 3
    pdf = np.zeros(1 << n)
    pdf[: 1 << (t // 2)] = 1.0  # only tiny operands -> no carries -> no error
    pdf /= pdf.sum()
    rep = error_metrics.mc_eval(n, t, samples=1 << 14, pdf_a=pdf, pdf_b=pdf)
    assert rep.er == 0.0 and rep.med_abs == 0.0


@pytest.mark.parametrize("order", [0, 1])
def test_estimator_tracks_exhaustive(order):
    """The #P-sidestepping estimator: per-cycle carry-crossing and the
    MAE-event probability must track ground truth within tolerance."""
    n, t = 8, 4
    est = error_model.estimate(n, t, order=order)
    ex = error_metrics.exhaustive_eval(n, t, fix_to_1=True)
    # ER upper estimate must be within [er_truth, 1] ballpark
    assert 0 < est.er_msp <= 1.0
    assert est.er_msp == pytest.approx(ex.er, rel=0.6)
    # fix-to-1 firing probability ~ P(C last cycle); sanity window
    assert 0.0 < est.p_fix < 0.5
    assert 0.0 < est.p_ed_mae < est.p_fix + 0.05


def test_estimator_order1_not_worse_than_order0():
    n, t = 8, 4
    ex = error_metrics.exhaustive_eval(n, t, fix_to_1=True)
    e0 = error_model.estimate(n, t, order=0)
    e1 = error_model.estimate(n, t, order=1)
    err0 = abs(e0.er_msp - ex.er)
    err1 = abs(e1.er_msp - ex.er)
    assert err1 <= err0 * 1.2  # cofactors should not systematically hurt


def test_estimator_biased_inputs():
    """Per-bit marginals feed the estimator (paper: measured input PDFs)."""
    n, t = 8, 4
    low = error_model.estimate(n, t, pa=np.full(n, 0.05), pb=np.full(n, 0.05))
    high = error_model.estimate(n, t, pa=np.full(n, 0.8), pb=np.full(n, 0.8))
    assert low.er_msp < high.er_msp
    assert low.med_abs_est < high.med_abs_est


@pytest.mark.parametrize("bad_call", [
    lambda: error_model.estimate(8, 0),
    lambda: error_model.estimate(8, 8),
    lambda: error_model.estimate(0, 1),
    lambda: error_model.estimate(33, 4),
    lambda: error_model.estimate(8, 4, pa=np.full(7, 0.5)),
    lambda: error_model.estimate(8, 4, pb=np.full(9, 0.5)),
    lambda: error_model.estimate(8, 4, pa=np.full(8, 1.5)),
    lambda: error_model.mae_closed_form(8, 0),
    lambda: error_model.max_ed_dropped_carry(8, 8),
])
def test_estimate_rejects_invalid_shapes(bad_call):
    """The estimator routes (n, t) through engine.recurrence.validate_nt
    and checks the marginal vectors — the invalid (n, t, pa, pb) it used
    to silently accept (t=0 wrapped pa[-1]; t>n reported a 0.0 LSP
    carry-out) now raise."""
    with pytest.raises(ValueError):
        bad_call()


def test_estimate_degenerate_n1_is_exact():
    """n=1, t=1 (the split validate_nt accepts since PR 3): single-cycle
    product, no carry to defer — every error metric is exactly zero, and
    mae_closed_form's explicit degenerate value replaces the raw
    formula's negative 2^{n+t-1} - 2^{t+1} = -2."""
    est = error_model.estimate(1, 1)
    assert est.er_msp == 0.0
    assert est.p_fix == 0.0
    assert est.med_abs_est == 0.0
    assert error_model.mae_closed_form(1, 1) == 0
    assert error_model.max_ed_dropped_carry(1, 1) == 0


@pytest.mark.parametrize("n", [1, 2, 3, 4])
def test_mae_closed_form_matches_boolean_enumeration(n):
    """Eq. 11 (and its explicit degenerate-case values) against exhaustive
    enumeration via the literal boolean reference: the closed form is the
    maximum *overshoot* (most negative ED) of the fix-disabled design —
    including the degenerate n=1 (exact) and n=2, t=1 (0) splits."""
    vals = np.arange(1 << n, dtype=np.uint64)
    a, b = [g.ravel() for g in np.meshgrid(vals, vals)]
    for t in range(1, max(1, n - 1) + 1):
        mae = error_model.mae_closed_form(n, t)
        assert mae >= 0
        phat = boolean_ref.int_from_bits(boolean_ref.mul_approx_bits(
            boolean_ref.bits_from_int(a, n), boolean_ref.bits_from_int(b, n),
            t=t, fix_to_1=False,
        ))
        ed = (a * b).astype(np.int64) - phat.astype(np.int64)
        assert int(-ed.min(initial=0)) == mae
