"""Self-speculative decoding correctness (repro.serve.strategy).

The strategy layer's contract, pinned in four layers:

* **Bit-match** — every token a ``SelfSpeculative`` pool commits is the
  *verify* engine's argmax, so its streams must bit-match plain greedy
  decode on the same pool — across mixed prompt lengths, mid-stream
  admission, and EOS retirement.  Rejected draft KV is rolled back on
  the host side (positions never advance past accepted tokens), and any
  contamination would show up here as a diverged stream.
* **Degenerate pair** — draft tier == verify tier proposes exactly what
  the verifier recomputes: accept rate must be exactly 1.0, matching
  ``engine_config.accept_rate_estimate``'s degenerate answer.
* **Accounting** — proposed/accepted/rolled-back counters must be
  conserved between the per-request and run-level views, and the
  summary line must render acceptance (with the ``n/a`` guard).
* **Surface** — the old scheduler-internal closures are gone; touching
  them must fail loudly with a pointer to the strategy module.
"""

import dataclasses

import jax
import numpy as np
import pytest

import repro.serve.scheduler as scheduler_mod
from repro.configs.registry import get_config
from repro.engine import config as engine_config
from repro.models.registry import build_model
from repro.serve import (
    GreedyDecode,
    Request,
    SelfSpeculative,
    continuous_serve_loop,
    get_strategy,
    static_serve_loop,
    synth_requests,
)
from repro.serve.policy import SLOAdaptive, StaticTier
from repro.serve.soak import run_soak
from repro.serve.workload import WorkloadSpec, generate

PROMPT, GEN = 8, 6


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen3-0.6b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _serve(model, params, queue, *, strategy, batch_size=2, **kw):
    return continuous_serve_loop(
        model, params, queue, batch_size=batch_size, prompt_len=PROMPT,
        max_new=GEN, warmup=False, strategy=strategy, **kw,
    )


def _assert_bit_match(plain, spec, queue):
    for r in queue:
        np.testing.assert_array_equal(
            plain.outputs[r.id], spec.outputs[r.id],
            err_msg=f"request {r.id}: speculative stream diverged from "
                    f"plain greedy decode",
        )


def test_speculative_bit_matches_plain_mixed_lengths(served):
    """Mixed-length prompts: speculative ≡ greedy, bit for bit."""
    cfg, model, params = served
    queue = synth_requests(
        6, prompt_len=PROMPT, gen=GEN, vocab_size=cfg.vocab_size, seed=0
    )
    assert len({r.prompt_len for r in queue}) > 1, "workload must mix lengths"
    plain = _serve(model, params, queue, strategy="greedy")
    spec = _serve(model, params, queue,
                  strategy=SelfSpeculative(k=4, draft_tier="draft"))
    _assert_bit_match(plain, spec, queue)
    assert spec.accounting.position_violations == 0
    assert spec.stats.spec_proposed > 0
    assert spec.stats.strategy == "speculative"
    assert plain.stats.strategy == "greedy"


def test_speculative_bit_matches_under_midstream_admission(served):
    """3 prompts on 2 slots: rollback + admission interleave cleanly."""
    cfg, model, params = served
    rng = np.random.default_rng(7)
    queue = [
        Request(id=0, tokens=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                max_new=2),
        Request(id=1, tokens=rng.integers(0, cfg.vocab_size, PROMPT).astype(np.int32),
                max_new=GEN),
        Request(id=2, tokens=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                max_new=2),
    ]
    spec = _serve(model, params, queue,
                  strategy=SelfSpeculative(k=3, draft_tier="draft"))
    assert spec.stats_for(2).admit_step > 0, "third request must admit mid-stream"
    # the oracle is the request served alone, unpadded, through the
    # static loop — the strongest form of the bit-match claim
    for r in queue:
        alone = static_serve_loop(
            model, params, [r], batch_size=1, prompt_len=r.prompt_len,
            gen=r.max_new, warmup=False,
        )
        np.testing.assert_array_equal(alone.outputs[r.id], spec.outputs[r.id])
    assert spec.accounting.position_violations == 0
    assert spec.accounting.slot_leaks == 0


def test_speculative_bit_matches_with_eos_retirement(served):
    """EOS mid-round: accepted tokens past EOS are discarded identically."""
    cfg, model, params = served
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, PROMPT).astype(np.int32)
               for _ in range(4)]
    probe = _serve(model, params, [Request(id=0, tokens=prompts[0], max_new=GEN)],
                   strategy="greedy")
    # request 0's mid-stream greedy token becomes the trace's EOS id, so
    # at least one row genuinely retires by EOS inside a speculated round
    eos = int(probe.outputs[0][GEN // 2])
    queue = [Request(id=i, tokens=p, max_new=GEN, eos_id=eos)
             for i, p in enumerate(prompts)]
    plain = _serve(model, params, queue, strategy="greedy")
    spec = _serve(model, params, queue,
                  strategy=SelfSpeculative(k=4, draft_tier="draft"))
    _assert_bit_match(plain, spec, queue)
    assert any(spec.stats_for(r.id).finish_reason == "eos" for r in queue)
    for r in queue:
        assert spec.stats_for(r.id).finish_reason == plain.stats_for(r.id).finish_reason


def test_degenerate_pair_accepts_everything(served):
    """draft == verify: the verifier recomputes the proposals exactly."""
    cfg, model, params = served
    queue = synth_requests(
        4, prompt_len=PROMPT, gen=GEN, vocab_size=cfg.vocab_size, seed=5
    )
    plain = _serve(model, params, queue, strategy="greedy")
    spec = _serve(model, params, queue,
                  strategy=SelfSpeculative(k=3, draft_tier="exact",
                                           verify_tier="exact"))
    _assert_bit_match(plain, spec, queue)
    assert spec.stats.spec_proposed > 0
    assert spec.stats.accept_rate == 1.0
    assert spec.stats.spec_rolled_back == 0
    assert engine_config.accept_rate_estimate("exact", "exact") == 1.0


def test_spec_counters_conserved_and_rendered(served):
    """Run-level counters == sum of per-request counters; summary renders."""
    cfg, model, params = served
    queue = synth_requests(
        4, prompt_len=PROMPT, gen=GEN, vocab_size=cfg.vocab_size, seed=2
    )
    spec = _serve(model, params, queue,
                  strategy=SelfSpeculative(k=4, draft_tier="draft"))
    st = spec.stats
    assert st.spec_proposed == sum(rs.proposed for rs in spec.request_stats)
    assert st.spec_accepted == sum(rs.accepted for rs in spec.request_stats)
    assert 0 <= st.spec_accepted <= st.spec_proposed
    assert st.spec_rolled_back == st.spec_proposed - st.spec_accepted
    for rs in spec.request_stats:
        assert rs.rolled_back == rs.proposed - rs.accepted
        if rs.proposed:
            assert rs.accept_rate == rs.accepted / rs.proposed
    # measured acceptance must sit above the error-model lower bound
    assert st.accept_rate >= engine_config.accept_rate_estimate("draft", "exact")
    assert "accept" in st.summary() and "[speculative]" in st.summary()
    plain = _serve(model, params, queue, strategy="greedy")
    assert "accept" not in plain.stats.summary()
    assert plain.stats.accept_rate is None
    # a speculative pool whose rounds never speculated renders the n/a guard
    idle = dataclasses.replace(st, spec_proposed=0, spec_accepted=0)
    assert "accept n/a" in idle.summary()


def test_request_strategy_tags_gate_speculation(served):
    """Tagged-request mixes switch strategy mid-stream; output unchanged."""
    cfg, model, params = served
    rng = np.random.default_rng(9)
    mk = lambda i, tag: Request(
        id=i, tokens=rng.integers(0, cfg.vocab_size, PROMPT).astype(np.int32),
        max_new=GEN, strategy=tag,
    )
    queue = [mk(0, "speculative"), mk(1, None), mk(2, None), mk(3, "speculative")]
    plain = _serve(model, params, queue, strategy="greedy")
    spec = _serve(model, params, queue,
                  strategy=SelfSpeculative(k=3, draft_tier="draft"))
    _assert_bit_match(plain, spec, queue)
    assert spec.stats.spec_rounds > 0  # tagged rows did drive speculation
    # an all-untagged queue speculates too (untagged rides the pool default)
    untagged = [mk(10, None), mk(11, None)]
    spec2 = _serve(model, params, untagged,
                   strategy=SelfSpeculative(k=3, draft_tier="draft"))
    assert spec2.stats.spec_rounds > 0
    with pytest.raises(ValueError, match="strategy"):
        Request(id=0, tokens=np.zeros(4, np.int32), max_new=1, strategy="beam")


def test_speculative_soak_passes_invariants_and_spot_checks(served):
    """A churn soak on a speculative pool keeps every audit green."""
    cfg, model, params = served
    spec = WorkloadSpec(
        requests=32, prompt_len=PROMPT, max_new=4, vocab_size=cfg.vocab_size,
        name="churn", arrival="poisson", rate_rps=256.0, prompt_dist="zipf",
        gen_dist="zipf", spec_fraction=0.5,
    )
    draw = generate(spec, seed=3)
    tags = {r.strategy for r in draw.requests}
    assert tags == {None, "speculative"}, "trace must mix tagged/untagged"
    report = run_soak(
        model, params, spec, batch_size=2, seed=3, window_size=16,
        spot_check=3, strategy=SelfSpeculative(k=3, draft_tier="draft"),
    )
    assert report.ok, report.violations
    assert report.spot_checks == 3 and report.spot_check_failures == 0
    assert report.strategy == "speculative"
    assert report.summary_row()["strategy"] == "speculative"
    with pytest.raises(ValueError, match="continuous"):
        run_soak(model, params, spec, batch_size=2, scheduler="static",
                 strategy="speculative")


def test_strategy_registry_and_validation():
    assert isinstance(get_strategy(None), GreedyDecode)
    assert isinstance(get_strategy("speculative"), SelfSpeculative)
    inst = SelfSpeculative(k=2, draft_tier="draft")
    assert get_strategy(inst) is inst
    with pytest.raises(ValueError):
        get_strategy("beam")
    with pytest.raises(ValueError):
        SelfSpeculative(k=0)
    with pytest.raises(ValueError, match="unknown quality tier"):
        SelfSpeculative(k=2, draft_tier="no-such-tier")


def test_old_scheduler_closures_fail_with_pointer():
    """The pre-refactor internals raise with a migration pointer."""
    for old in ("_TierEngine", "_build_engine", "decode_greedy", "pump"):
        with pytest.raises(AttributeError, match="repro.serve.strategy"):
            getattr(scheduler_mod, old)
    with pytest.raises(AttributeError):
        scheduler_mod.no_such_symbol  # plain miss keeps the plain error


def test_sloadaptive_speculation_gate_is_deterministic():
    """The policy gate is a pure function of the modeled gain."""
    pol = SLOAdaptive(slo_ttft_s=0.05, spec_draft_tier="draft", spec_k=4)
    snap = None  # the gate never inspects the snapshot today
    gain = engine_config.speculation_gain("draft", pol.ladder[pol._rung], 4)
    assert pol.speculation(snap) == (gain > 1.0)
    # the gate-delay cost model prices a draft step at 0.55x an exact
    # step, so no registered pair clears break-even — documented honest
    # finding, and exactly why StaticTier never declines speculation
    assert StaticTier().speculation(snap) is True
    with pytest.raises(ValueError):
        SLOAdaptive(slo_ttft_s=0.05, spec_k=0)
