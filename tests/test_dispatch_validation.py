"""Eager dispatch-time (n, t) rejection across every mode boundary.

Each structural limit the static auditor derives (`tests/test_analysis`)
is also enforced eagerly at dispatch, with the mode named in the error —
these tests pin the messages so a widened kernel cannot silently ship
behind a stale guard."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.kernels.approx_attention import validate_attn_mode
from repro.kernels.seqmul_kernel import seqmul_pallas_words


def _ops(m=8, k=8, n_out=8):
    return (jnp.asarray(np.ones((m, k)), jnp.float32),
            jnp.asarray(np.ones((k, n_out)), jnp.float32))


# one past each mode's structural ceiling; the message must name the
# mode and the limit so the failure is actionable from a model stack.
_OVER_LIMIT = [
    ("bitexact", 9, 4, "n <= 8"),
    ("lowrank", 9, 4, "n <= 8"),
    ("seqmul", 13, 6, "n <= 12"),
    ("inject", 16, 8, "n <= 15"),
    ("fakequant", 24, 12, "n <= 23"),
]


@pytest.mark.parametrize("mode,n,t,limit", _OVER_LIMIT)
def test_matmul_rejects_over_limit_n_eagerly(mode, n, t, limit):
    x, w = _ops()
    with pytest.raises(ValueError) as ei:
        engine.matmul(x, w, n=n, t=t, mode=mode,
                      **({"key": jnp.zeros((2,), jnp.uint32)}
                         if engine.get_mode(mode).needs_key else {}))
    msg = str(ei.value)
    assert repr(mode) in msg or f"mode '{mode}'" in msg
    assert limit in msg


# widest n each mode actually dispatches at (inject's error LUT caps at
# n=10 even though its int16 packing admits 15)
_ACCEPT = [
    ("bitexact", 8, 4),
    ("lowrank", 8, 4),
    ("seqmul", 12, 6),
    ("inject", 10, 5),
    ("fakequant", 23, 11),
]


@pytest.mark.parametrize("mode,n,t", _ACCEPT)
def test_widest_supported_n_is_accepted(mode, n, t):
    """The eager guard must not misfire below each mode's ceiling."""
    x, w = _ops()
    kw = {}
    if engine.get_mode(mode).needs_key:
        kw["key"] = engine_key()
    out = engine.matmul(x, w, n=n, t=t, mode=mode,
                        backend="reference", **kw)
    assert out.shape == (8, 8)


def engine_key():
    import jax

    return jax.random.PRNGKey(0)


def test_multiply_rejects_packed_2n_32():
    a = jnp.ones((4,), jnp.uint32)
    with pytest.raises(ValueError) as ei:
        engine.multiply(a, a, n=16, t=8)
    msg = str(ei.value)
    assert "seqmul_approx" in msg
    assert "2n <= 31" in msg
    assert "seqmul_pallas_words" in msg  # the documented escape hatch


def test_multiply_accepts_packed_boundary_n15():
    a = jnp.asarray([3], jnp.uint32)
    out = engine.multiply(a, a, n=15, t=7, backend="reference")
    assert out.dtype == jnp.uint32


def test_two_word_kernel_rejects_n17():
    a = jnp.ones((4,), jnp.uint32)
    with pytest.raises(ValueError) as ei:
        seqmul_pallas_words(a, a, n=17, t=8)
    msg = str(ei.value)
    assert "n <= 16" in msg
    assert "two-word" in msg


def test_attention_rejects_n9():
    with pytest.raises(ValueError) as ei:
        validate_attn_mode("bitexact", 9)
    msg = str(ei.value)
    assert "n <= 8" in msg


def test_invalid_split_t_names_mode():
    x, w = _ops()
    with pytest.raises(ValueError) as ei:
        engine.matmul(x, w, n=8, t=8, mode="seqmul")
    msg = str(ei.value)
    assert "'seqmul'" in msg and "t <= n-1" in msg


def test_tile_validation_error_names_mode_n_t():
    from repro.analysis.vmem import TileBudgetError, validate_tiles

    with pytest.raises(TileBudgetError) as ei:
        validate_tiles("bitexact", 8, 4, (512, 512, 512))
    msg = str(ei.value)
    assert "bitexact" in msg and "n=8" in msg and "t=4" in msg
