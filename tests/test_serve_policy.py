"""Admission policies + open-loop clocked scheduling (repro.serve.policy).

Four layers of guarantees:

* **Policy units** — ``get_policy`` resolution, ``Reject`` shedding at
  its depth bound, ``SLOAdaptive`` ladder validation against the
  ``engine.config`` controller, and the hysteresis state machine driven
  by synthetic :class:`LoadSnapshot` ticks (degrade streak, recovery
  streak, the ``min_dwell_ticks`` refractory window that forbids
  oscillation on the boundary).
* **Open-loop semantics** — ``StaticTier`` with everything arriving at
  t=0 bit-matches the closed-loop scheduler; TTFT/latency are re-based
  to *arrival* (queueing included) with ``queue_delay_s`` split out;
  ``ServeStats.summary`` renders the open-loop fields with the
  n/a-on-empty guards.
* **Deterministic adaptation** — the same seeded bursty trace on the
  virtual clock replays the identical tier-switch sequence, with both a
  degrade and a recovery observed.
* **The acceptance comparison** — on the benchmark's bursty trace,
  SLOAdaptive attains strictly more TTFT SLOs than StaticTier(high) at
  an equal-or-better queue-delay p99, with zero starved requests (the
  reduced-size twin of the gated ``BENCH_serve_throughput.json`` rows
  CI compares against).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.engine.config import ripple_delay, segmented_delay, tier_cycle_factor
from repro.models.registry import build_model
from repro.serve import (
    ContinuousScheduler,
    Request,
    Reject,
    SLOAdaptive,
    StaticTier,
    continuous_serve_loop,
    get_policy,
    synth_requests,
)
from repro.serve.policy import AdmissionPolicy, LoadSnapshot
from repro.serve.request import RequestStats
from repro.serve.stats import ServeStats
from repro.serve.workload import generate, preset_spec

PROMPT, GEN = 8, 4


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen3-0.6b").reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _snap(queue_depth, *, step=0, now=0.0, batch=4):
    return LoadSnapshot(now_s=now, step=step, queue_depth=queue_depth,
                        pending=0, live_rows=batch, batch_size=batch)


# ------------------------------------------------------------- policy units
def test_get_policy_resolution():
    assert isinstance(get_policy("static"), StaticTier)
    assert isinstance(get_policy("slo-adaptive"), SLOAdaptive)
    assert isinstance(get_policy("reject"), Reject)
    inst = Reject(max_queue_depth=3)
    assert get_policy(inst) is inst
    with pytest.raises(ValueError, match="policy kwargs"):
        get_policy(inst, max_queue_depth=5)
    with pytest.raises(ValueError, match="unknown admission policy"):
        get_policy("fifo")


def test_reject_sheds_beyond_depth_bound():
    pol = Reject(max_queue_depth=3)
    req = Request(id=0, tokens=np.zeros(4, np.int32), max_new=1)
    assert pol.admit(req, _snap(3))
    assert not pol.admit(req, _snap(4))
    # default bound scales with the pool: depth_factor * batch_size
    pol = Reject(depth_factor=2.0)
    assert pol.admit(req, _snap(8, batch=4))
    assert not pol.admit(req, _snap(9, batch=4))
    with pytest.raises(ValueError, match="max_queue_depth"):
        Reject(max_queue_depth=0)
    with pytest.raises(ValueError, match="depth_factor"):
        Reject(depth_factor=0.0)


def test_slo_adaptive_validates_ladder_via_controller():
    with pytest.raises(ValueError, match="unknown quality tier"):
        SLOAdaptive(ladder=("high", "turbo"))
    with pytest.raises(ValueError, match="ladder"):
        SLOAdaptive(ladder=("high",))
    with pytest.raises(ValueError, match="slo_ttft_s"):
        SLOAdaptive(slo_ttft_s=0.0)
    pol = SLOAdaptive()
    # every rung is pre-resolved by the engine.config controller
    assert set(pol.resolutions) == set(pol.ladder)
    for qc in pol.resolutions.values():
        assert qc.per_target


def test_slo_adaptive_hysteresis_state_machine():
    pol = SLOAdaptive(slo_ttft_s=1.0, degrade_after=2, recover_after=3,
                      min_dwell_ticks=3, queue_high=2.0, queue_low=0.5)
    pol.begin("high")
    hot, calm = _snap(100), _snap(0)
    assert pol.tier(hot) == "high"  # one breach is not a streak
    assert pol.tier(hot) == "balanced"  # second consecutive breach degrades
    assert [s.reason for s in pol.switches] == ["degrade:queue"]
    # refractory window: breaches keep arriving but no switch may fire
    for _ in range(3):
        assert pol.tier(hot) == "balanced"
    assert len(pol.switches) == 1
    # once the dwell expires, the (still-standing) breach streak degrades again
    assert pol.tier(hot) == "draft"
    # recovery needs recover_after calm ticks *and* an expired dwell window
    for _ in range(3):
        assert pol.tier(calm) == "draft"
    assert pol.tier(calm) == "balanced"
    assert pol.switches[-1].reason == "recover"
    # a fresh breach inside the new dwell window cannot oscillate back
    assert pol.tier(hot) == "balanced"
    assert pol.tier(hot) == "balanced"
    assert len(pol.switches) == 3


_RS = RequestStats(id=0, prompt_len=4, tokens_out=1, admit_step=0,
                   ttft_s=0.0, latency_s=0.0, finish_reason="budget")


def test_slo_adaptive_ttft_signal_degrades():
    pol = SLOAdaptive(slo_ttft_s=0.1, degrade_after=2, min_dwell_ticks=0)
    pol.begin("high")
    for _ in range(8):  # rolling window full of SLO-violating TTFTs
        pol.observe(dataclasses.replace(_RS, ttft_s=0.5))
    calm_depth = _snap(0)
    pol.tier(calm_depth)
    assert pol.tier(calm_depth) == "balanced"
    assert pol.switches[0].reason == "degrade:ttft"


def test_tier_cycle_factor_monotone():
    # the virtual clock's tier cost model: segmented tiers finish their
    # carry chains in fewer cycles, so factors fall monotonically
    assert tier_cycle_factor(None) == 1.0
    assert tier_cycle_factor("exact") == 1.0
    f = [tier_cycle_factor(t) for t in ("high", "balanced", "draft")]
    assert f[0] > f[1] > f[2] > 0.0
    # consistent with the paper's gate-delay model over the controller's
    # per-target resolution
    from repro.engine.config import resolve_tier

    qc = resolve_tier("high")
    expected = np.mean(
        [segmented_delay(q.n, q.t) for q in qc.per_target]
    ) / ripple_delay(8)
    assert tier_cycle_factor("high") == pytest.approx(expected)


# -------------------------------------------------------- open-loop semantics
def test_open_loop_static_bitmatches_closed_loop(served):
    cfg, model, params = served
    queue = synth_requests(8, prompt_len=PROMPT, gen=GEN,
                           vocab_size=cfg.vocab_size, seed=11)
    closed = continuous_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, max_new=GEN,
        warmup=False,
    )
    opened = continuous_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, max_new=GEN,
        warmup=False, arrivals_s=[0.0] * len(queue), policy=StaticTier(),
    )
    assert opened.stats.open_loop and not closed.stats.open_loop
    assert opened.stats.policy == "static"
    for r in queue:
        np.testing.assert_array_equal(closed.outputs[r.id], opened.outputs[r.id])
    assert [rs.id for rs in closed.request_stats] == \
           [rs.id for rs in opened.request_stats]
    assert opened.stats.starved == 0 and opened.stats.rejected == 0


def test_open_loop_ttft_rebased_to_arrival(served):
    cfg, model, params = served
    queue = synth_requests(6, prompt_len=PROMPT, gen=GEN,
                           vocab_size=cfg.vocab_size, seed=13)
    arrivals = [0.4 * i for i in range(len(queue))]
    result = continuous_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, max_new=GEN,
        warmup=False, arrivals_s=arrivals, step_time_s=0.01,
    )
    by_id = {rs.id: rs for rs in result.request_stats}
    for req, arr in zip(queue, arrivals):
        rs = by_id[req.id]
        assert rs.arrival_s == pytest.approx(arr)
        # arrival-based decomposition: ttft = queue wait + admission cost
        assert rs.queue_delay_s is not None and rs.queue_delay_s >= 0.0
        assert rs.ttft_s >= rs.queue_delay_s
        assert rs.latency_s >= rs.ttft_s
    assert len(result.stats.queue_delay_s) == len(queue)


def test_open_loop_requires_valid_arrivals(served):
    cfg, model, params = served
    queue = synth_requests(3, prompt_len=PROMPT, gen=GEN,
                           vocab_size=cfg.vocab_size, seed=1)
    sched = ContinuousScheduler(model, params, batch_size=2,
                                prompt_len=PROMPT, max_new=GEN)
    with pytest.raises(ValueError, match="arrivals"):
        sched.run(queue, warmup=False, arrivals_s=[0.0])
    with pytest.raises(ValueError, match="non-decreasing"):
        sched.run(queue, warmup=False, arrivals_s=[1.0, 0.5, 2.0])
    with pytest.raises(ValueError, match="step_time_s"):
        sched.run(queue, warmup=False, arrivals_s=[0.0, 0.0, 0.0],
                  step_time_s=0.0)
    with pytest.raises(ValueError, match="clock"):
        sched.run(queue, warmup=False, arrivals_s=[0.0, 0.0, 0.0],
                  clock="sundial")


def test_summary_renders_open_loop_fields():
    base = dict(requests=4, tokens_out=16, wall_s=1.0, prefill_s=0.2,
                decode_s=0.8, batch_latencies_s=(), devices=1,
                scheduler="continuous")
    closed = ServeStats(**base)
    assert "queue p50" not in closed.summary()
    empty = ServeStats(**base, open_loop=True, policy="static")
    # n/a-on-empty guard: no queue delays / SLOs recorded yet
    assert "queue p50 n/a" in empty.summary()
    assert ", slo " not in empty.summary()
    full = ServeStats(**base, open_loop=True, policy="slo-adaptive",
                      queue_delay_s=(0.1, 0.2), tier_switches=3, rejected=1,
                      slo_total=4, slo_attained=3)
    s = full.summary()
    assert "queue p50" in s and "slo 75%" in s
    assert "3 tier switches" in s and "1 rejected" in s
    assert "[slo-adaptive]" in s
    assert full.slo_attainment == pytest.approx(0.75)
    assert ServeStats(**base).slo_attainment is None


# --------------------------------------------------- deterministic adaptation
def _burst_then_quiet(cfg):
    """20 requests at t=0 (queue blows past queue_high) then a widely
    spaced tail (queue drains to zero so recovery streaks can build)."""
    queue = synth_requests(32, prompt_len=PROMPT, gen=GEN,
                           vocab_size=cfg.vocab_size, seed=17,
                           vary_budget=False)
    arrivals = [0.0] * 20 + [2.0 + 0.3 * i for i in range(12)]
    return queue, arrivals


def _adaptive():
    # queue-driven only (slo_ttft_s huge): deterministic from the trace
    return SLOAdaptive(slo_ttft_s=100.0, degrade_after=2, recover_after=3,
                       min_dwell_ticks=3)


def test_slo_adaptive_replays_identical_switch_sequence(served):
    cfg, model, params = served

    def run():
        queue, arrivals = _burst_then_quiet(cfg)
        result = continuous_serve_loop(
            model, params, queue, batch_size=4, prompt_len=PROMPT,
            max_new=GEN, warmup=False, quality="high",
            arrivals_s=arrivals, policy=_adaptive(), step_time_s=0.01,
        )
        return result

    a, b = run(), run()
    sig_a = [(s.step, s.from_tier, s.to_tier, s.reason) for s in a.tier_switches]
    sig_b = [(s.step, s.from_tier, s.to_tier, s.reason) for s in b.tier_switches]
    assert sig_a == sig_b  # seeded trace => identical switch sequence
    assert [s.now_s for s in a.tier_switches] == [s.now_s for s in b.tier_switches]
    reasons = [s.reason for s in a.tier_switches]
    assert any(r.startswith("degrade:") for r in reasons)
    assert "recover" in reasons
    # the event stream is internally consistent: each switch leaves from
    # the tier the previous one arrived at, at nondecreasing times
    for prev, cur in zip(a.tier_switches, a.tier_switches[1:]):
        assert cur.from_tier == prev.to_tier
        assert cur.now_s >= prev.now_s
    assert a.stats.tier_switches == len(reasons)
    assert a.stats.starved == 0
    # served tiers are recorded per request and only name ladder rungs
    tiers = {rs.tier_served for rs in a.request_stats}
    assert tiers <= {"high", "balanced", "draft"}
    assert len(tiers) > 1  # the pool really did serve at multiple tiers


def test_reject_policy_sheds_and_counts_slo(served):
    cfg, model, params = served
    queue = [dataclasses.replace(r, slo_ttft_s=10.0)
             for r in synth_requests(12, prompt_len=PROMPT, gen=GEN,
                                     vocab_size=cfg.vocab_size, seed=19)]
    result = continuous_serve_loop(
        model, params, queue, batch_size=2, prompt_len=PROMPT, max_new=GEN,
        warmup=False, arrivals_s=[0.0] * len(queue),
        policy=Reject(max_queue_depth=2), step_time_s=0.01,
    )
    stats = result.stats
    assert stats.rejected > 0
    assert stats.requests + stats.rejected == len(queue)
    assert stats.starved == 0
    for rs in result.rejected:
        assert rs.finish_reason == "rejected"
        assert rs.id not in result.outputs
    # rejected SLO-carrying requests count against attainment: the
    # denominator is *offered*, so shedding cannot game the metric
    assert stats.slo_total == len(queue)
    assert stats.slo_attained <= stats.requests
    assert stats.slo_attainment < 1.0


# ------------------------------------------------------ acceptance comparison
def test_adaptive_beats_static_high_on_bursty_trace(served):
    """Reduced twin of the gated BENCH_serve_throughput open-loop rows:
    on the same seeded bursty trace, SLOAdaptive must attain strictly
    more TTFT SLOs than StaticTier with the pool pinned at ``high``, at
    an equal-or-better queue-delay p99, and neither run may starve or
    shed a request.  CI gates the committed baseline numbers; this test
    pins the comparison itself."""
    from repro.serve.stats import percentile

    cfg, model, params = served
    spec = preset_spec("bursty", requests=48, prompt_len=PROMPT, max_new=6,
                       vocab_size=cfg.vocab_size, rate_rps=256.0,
                       slo_ttft_s=0.05)
    draw = generate(spec, seed=0)
    results = {}
    for policy in (StaticTier(),
                   SLOAdaptive(slo_ttft_s=0.05, degrade_after=2,
                               recover_after=4, min_dwell_ticks=4)):
        sched = ContinuousScheduler(model, params, batch_size=4,
                                    prompt_len=PROMPT, max_new=6,
                                    quality="high")
        results[policy.name] = sched.run(
            list(draw.requests), warmup=False,
            arrivals_s=list(draw.arrivals_s), policy=policy,
            step_time_s=0.01,
        ).stats
    st, ad = results["static"], results["slo-adaptive"]
    assert st.starved == ad.starved == 0
    assert st.rejected == ad.rejected == 0
    assert st.tier_switches == 0 and ad.tier_switches > 0
    assert ad.slo_attainment > st.slo_attainment
    assert percentile(ad.queue_delay_s, 99) <= percentile(st.queue_delay_s, 99)


def test_closed_loop_accepts_explicit_policy(served):
    cfg, model, params = served
    queue = synth_requests(3, prompt_len=PROMPT, gen=GEN,
                           vocab_size=cfg.vocab_size, seed=2)
    sched = ContinuousScheduler(model, params, batch_size=2,
                                prompt_len=PROMPT, max_new=GEN)
    # closed loop + an explicit policy is fine (StaticTier is implicit
    # today); the policy still sees admissions
    result = sched.run(queue, warmup=False, policy=AdmissionPolicy())
    assert result.stats.requests == len(queue)
    assert not result.stats.open_loop
