"""Engine dispatch: reference-vs-Pallas parity for every registered mode,
registry error behavior, and the engine-level straight-through gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine


def _operands(m, k, n_out, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n_out)), jnp.float32)
    return x, w


def _kwargs(mode, n, t, fix):
    kw = dict(n=n, t=t, fix_to_1=fix, mode=mode, rank=8)
    if engine.get_mode(mode).needs_key:
        kw["key"] = jax.random.PRNGKey(7)
    return kw


@pytest.mark.parametrize("n,t,fix", [(8, 4, True), (8, 2, False), (6, 3, True), (4, 1, True)])
@pytest.mark.parametrize("mode", sorted(engine.list_modes()))
def test_backend_parity_bit_identical(mode, n, t, fix):
    """Every mode with a Pallas body must produce bit-identical results on
    the reference and Pallas backends.  Under native lowering (TPU) the
    tiled MXU accumulation order may differ in float LSBs, so there
    parity is tight-allclose instead.  (Modes without a Pallas body
    reject an explicit backend='pallas' — covered separately.)"""
    if engine.get_mode(mode).pallas is None:
        pytest.skip(f"mode {mode!r} has no Pallas body")
    x, w = _operands(32, 64, 16, seed=n * 10 + t)
    kw = _kwargs(mode, n, t, fix)
    ref = np.asarray(engine.matmul(x, w, backend="reference", **kw))
    pal = np.asarray(engine.matmul(x, w, backend="pallas", **kw))
    if engine.use_interpret():
        np.testing.assert_array_equal(ref, pal)
    else:
        np.testing.assert_allclose(ref, pal, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", sorted(engine.list_modes()))
def test_auto_backend_matches_reference(mode):
    """'auto' resolves within the declared backend set and, on CPU (no
    native Pallas), must produce the reference result."""
    x, w = _operands(16, 32, 8, seed=3)
    kw = _kwargs(mode, 8, 4, True)
    auto = np.asarray(engine.matmul(x, w, backend="auto", **kw))
    ref = np.asarray(engine.matmul(x, w, backend="reference", **kw))
    if engine.use_interpret():
        np.testing.assert_array_equal(auto, ref)
    else:  # native TPU: still numerically the same computation
        np.testing.assert_allclose(auto, ref, rtol=1e-5, atol=1e-5)


def test_multiply_backend_parity():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(0, 1 << 8, size=(16, 130)), jnp.uint32)
    b = jnp.asarray(rng.integers(0, 1 << 8, size=(16, 130)), jnp.uint32)
    for approx in (True, False):
        ref = np.asarray(engine.multiply(a, b, n=8, t=4, approx=approx, backend="reference"))
        pal = np.asarray(engine.multiply(a, b, n=8, t=4, approx=approx, backend="pallas"))
        np.testing.assert_array_equal(ref, pal)


def test_unknown_mode_lists_valid_names():
    x, w = _operands(4, 4, 4)
    with pytest.raises(ValueError) as ei:
        engine.matmul(x, w, mode="nope")
    for name in engine.list_modes():
        assert name in str(ei.value)


def test_unknown_backend_lists_valid_names():
    x, w = _operands(4, 4, 4)
    with pytest.raises(ValueError) as ei:
        engine.matmul(x, w, mode="exact", backend="cuda")
    for name in engine.BACKENDS:
        assert name in str(ei.value)
    with pytest.raises(ValueError):
        engine.multiply(jnp.uint32(1), jnp.uint32(1), backend="cuda")


def test_stochastic_mode_requires_key():
    x, w = _operands(4, 4, 4)
    with pytest.raises(ValueError, match="key"):
        engine.matmul(x, w, mode="inject")


def test_duplicate_mode_registration_rejected():
    spec = engine.get_mode("exact")
    with pytest.raises(ValueError, match="already registered"):
        engine.register_mode(spec)


def test_explicit_pallas_on_mode_without_body_raises():
    """backend='pallas' on a mode with no Pallas body must not silently run
    the reference body — that is an explicit request; only 'auto' falls
    back."""
    x, w = _operands(4, 4, 4)
    for mode in sorted(engine.list_modes()):
        spec = engine.get_mode(mode)
        if spec.pallas is not None:
            continue
        with pytest.raises(ValueError, match=mode):
            engine.matmul(x, w, mode=mode, backend="pallas",
                          **({"key": jax.random.PRNGKey(0)} if spec.needs_key else {}))
        # 'auto' keeps the documented reference fallback
        kw = _kwargs(mode, 8, 4, True)
        out = engine.matmul(x, w, backend="auto", **kw)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(engine.matmul(x, w, backend="reference", **kw))
        )


def test_straight_through_integer_extra_cotangent():
    """A mode whose ``prepare`` returns integer arrays (e.g. an int32 LUT)
    must still be trainable: zero cotangents are cast to the tangent type
    (float0 for ints) instead of crashing ``custom_vjp`` under grad."""
    from repro.engine import modes as engine_modes

    name = "_test_int_extra"

    def prepare(x, w, p, key):
        lut = jnp.arange(16, dtype=jnp.int32)  # int32 extra: the crash case
        return (lut, jnp.float32(0.5))

    def ref(x, w, p, lut, scale):
        return (x @ w) * scale + lut.sum().astype(jnp.float32) * 0.0

    engine.register_mode(engine_modes.ModeSpec(
        name=name, reference=ref, prepare=prepare, differentiable=False,
        description="test-only: int32 extra under straight-through",
    ))
    try:
        x, w = _operands(4, 6, 3, seed=2)
        gx, gw = jax.grad(
            lambda x, w: engine.matmul(x, w, mode=name).sum(), argnums=(0, 1)
        )(x, w)
        # straight-through: backward is the *exact-matmul* VJP, scale ignored
        ex, ew = jax.grad(lambda x, w: (x @ w).sum(), argnums=(0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(ex), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(gw), np.asarray(ew), rtol=1e-6)
    finally:
        engine_modes._REGISTRY.pop(name, None)


@pytest.mark.parametrize(
    "mode", [m for m in sorted(engine.list_modes()) if not engine.get_mode(m).differentiable]
)
def test_every_nondifferentiable_mode_is_trainable(mode):
    """jax.grad must run through every registered non-differentiable mode
    (the engine's straight-through rule, whatever the mode's extras)."""
    x, w = _operands(6, 8, 4, seed=13)
    kw = _kwargs(mode, 8, 4, True)
    gx, gw = jax.grad(
        lambda x, w: engine.matmul(x, w, **kw).sum(), argnums=(0, 1)
    )(x, w)
    assert np.isfinite(np.asarray(gx)).all() and np.isfinite(np.asarray(gw)).all()
    assert float(np.abs(np.asarray(gx)).sum()) > 0


@pytest.mark.parametrize("mode", ["bitexact", "lowrank", "inject"])
def test_straight_through_gradients(mode):
    """Non-differentiable modes get exact-matmul gradients at the engine
    level: nonzero, and equal to the gradients of x @ w."""
    x, w = _operands(8, 16, 4, seed=9)
    kw = _kwargs(mode, 8, 4, True)

    def loss(x, w):
        return (engine.matmul(x, w, **kw) * 0.5).sum()

    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w)
    ex, ew = jax.grad(lambda x, w: ((x @ w) * 0.5).sum(), argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ex), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(ew), rtol=1e-6)


@pytest.mark.parametrize("mode", sorted(engine.list_modes()))
def test_moe_expert_gemm_routes_through_engine(mode):
    """'moe'-targeted approximation uses the registry for every mode —
    including stochastic ones (per-expert keys), which used to crash."""
    from repro.configs.registry import apply_approx, get_config
    from repro.models import moe
    from repro.models.layers import Ctx

    cfg = get_config("granite-moe-1b-a400m").reduced(
        num_experts=4, num_experts_per_tok=2, moe_d_ff=16, d_model=32,
        capacity_factor=8.0)
    acfg = apply_approx(cfg, mode=mode, targets=("moe",))
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32), jnp.float32)
    out, _ = moe.moe_ffn(params, x, Ctx(cfg=acfg, rng=jax.random.PRNGKey(3)))
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())


def test_engine_matches_legacy_entry_points():
    """The migration shims (core.approx_matmul / kernels.ops) and the
    engine agree — old call sites keep their semantics."""
    from repro.core.approx_matmul import approx_matmul
    from repro.kernels.ops import approx_matmul_kernel

    x, w = _operands(16, 48, 8, seed=11)
    for mode in ("bitexact", "lowrank"):
        legacy_ref = np.asarray(approx_matmul(x, w, n=8, t=4, mode=mode))
        legacy_pal = np.asarray(approx_matmul_kernel(x, w, n=8, t=4, mode=mode))
        new_ref = np.asarray(engine.matmul(x, w, n=8, t=4, mode=mode, backend="reference"))
        np.testing.assert_array_equal(legacy_ref, new_ref)
        np.testing.assert_allclose(legacy_pal, new_ref, rtol=1e-5, atol=1e-5)
