"""Lint gate: `ruff check` over src/ and tests/ with the committed
pyproject config.  Skips when ruff is not installed (the CI
static-analysis job installs it; the kernel image does not ship it)."""

import pathlib
import shutil
import subprocess

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def _ruff():
    exe = shutil.which("ruff")
    if exe:
        return [exe]
    probe = subprocess.run(
        ["python", "-m", "ruff", "--version"], capture_output=True, cwd=REPO
    )
    if probe.returncode == 0:
        return ["python", "-m", "ruff"]
    return None


@pytest.fixture(scope="module")
def ruff_cmd():
    cmd = _ruff()
    if cmd is None:
        pytest.skip("ruff not installed (CI installs it for the lint gate)")
    return cmd


def test_ruff_check_clean(ruff_cmd):
    proc = subprocess.run(
        [*ruff_cmd, "check", "src", "tests"],
        capture_output=True, text=True, cwd=REPO,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_ruff_config_committed(ruff_cmd):
    """The lint surface is pinned by pyproject, not ruff defaults."""
    assert (REPO / "pyproject.toml").read_text().count("[tool.ruff")
    proc = subprocess.run(
        [*ruff_cmd, "check", "--show-settings", "src/repro/__init__.py"],
        capture_output=True, text=True, cwd=REPO,
    )
    assert proc.returncode == 0, proc.stderr
