"""Checkpoint manager (async, atomic, elastic) + fault-tolerant loop."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.registry import build_model
from repro.runtime.fault import FailureInjector, StragglerMonitor, run_loop
from repro.train.steps import init_train_state, make_train_step


def _setup(tmp_path, steps=12, ckpt_every=4, compress=0):
    cfg = get_config("qwen3-0.6b").reduced(num_layers=2, d_model=32, d_ff=64,
                                           vocab_size=64, num_heads=2,
                                           num_kv_heads=1, head_dim=8)
    m = build_model(cfg)
    tcfg = TrainConfig(total_steps=steps, warmup_steps=2, learning_rate=1e-3,
                       grad_compress_bits=compress)
    state = init_train_state(m, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(m, tcfg))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4))

    def batch_fn(i):
        return {k: jnp.asarray(v) for k, v in data.batch(i).items()}

    return state, step, batch_fn, steps, ckpt_every


def test_checkpoint_roundtrip(tmp_path):
    state, step, batch_fn, _, _ = _setup(tmp_path)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state1, _ = step(state, batch_fn(0))
    mgr.save(1, state1, blocking=True)
    assert mgr.latest_step() == 1
    restored, at = mgr.restore(state)
    assert at == 1
    for a, b in zip(jax.tree_util.tree_leaves(state1), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_prune_and_async(tmp_path):
    state, step, batch_fn, _, _ = _setup(tmp_path)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for i in range(5):
        mgr.save(i, state)  # async
    mgr.wait()
    files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(files) <= 2
    assert mgr.latest_step() == 4


def test_checkpoint_structure_mismatch(tmp_path):
    state, step, batch_fn, _, _ = _setup(tmp_path)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(0, {"a": jnp.zeros(3)}, blocking=True)
    with pytest.raises(ValueError):
        mgr.restore(state)


def test_run_loop_recovers_from_failures(tmp_path):
    """Injected failures + restore must reproduce the exact no-failure run
    (counter-based data + checkpointed state => bitwise determinism)."""
    state, step, batch_fn, steps, every = _setup(tmp_path)
    clean = run_loop(state, step, batch_fn, total_steps=steps)
    state2, step2, _, _, _ = _setup(tmp_path)
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=3)
    faulty = run_loop(
        state2, step2, batch_fn, total_steps=steps, ckpt=mgr, checkpoint_every=every,
        injector=FailureInjector(fail_at=(5, 9)), max_failures=5,
    )
    assert faulty.failures == 2
    assert faulty.restarts >= 2
    np.testing.assert_allclose(
        clean.metrics_history[-1]["loss"], faulty.metrics_history[-1]["loss"],
        rtol=1e-6,
    )
    assert int(faulty.state.step) == steps


def test_run_loop_exceeds_max_failures(tmp_path):
    state, step, batch_fn, steps, _ = _setup(tmp_path)
    with pytest.raises(RuntimeError, match="max_failures"):
        run_loop(state, step, batch_fn, total_steps=steps,
                 injector=FailureInjector(fail_at=(2,)), max_failures=0)


def test_straggler_monitor():
    mon = StragglerMonitor(factor=3.0, warmup=2)
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 1.0)  # 10x EMA -> straggler
    assert mon.slow_steps and mon.slow_steps[0][0] == 10
    # EMA not polluted by the outlier
    assert mon.ema == pytest.approx(0.1, rel=0.05)


def test_data_pipeline_determinism_and_sharding():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8)
    d1 = SyntheticLM(cfg)
    d2 = SyntheticLM(cfg)
    np.testing.assert_array_equal(d1.batch(7)["tokens"], d2.batch(7)["tokens"])
    # host sharding partitions the global batch
    h0 = SyntheticLM(cfg, process_index=0, process_count=2)
    h1 = SyntheticLM(cfg, process_index=1, process_count=2)
    full = d1.batch(3)["tokens"]
    np.testing.assert_array_equal(h0.batch(3)["tokens"], full[:4])
    np.testing.assert_array_equal(h1.batch(3)["tokens"], full[4:])
