"""End-to-end driver: train an LM whose MLP GEMMs run through the
segmented-carry-chain approximate multiplier, vs. the exact baseline.

Uses the fault-tolerant loop with checkpointing; pass --steps 300 for the
full run (CPU: a reduced ~1M-param qwen3; on a real pod drop --reduced to
train the full architecture).

  PYTHONPATH=src python examples/train_approx_lm.py --steps 120
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig
from repro.configs.registry import apply_approx, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.engine import modes as engine_modes
from repro.models.registry import build_model
from repro.runtime.fault import run_loop
from repro.train.steps import init_train_state, make_train_step


def train(cfg, steps, seed=0, ckpt_dir=None):
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=10, total_steps=steps)
    state = init_train_state(model, tcfg, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=0)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8, seed=seed))
    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    res = run_loop(
        state, step, lambda i: {k: jnp.asarray(v) for k, v in data.batch(i).items()},
        total_steps=steps, ckpt=ckpt, checkpoint_every=50 if ckpt else 0,
        log_every=max(1, steps // 6),
    )
    return [h["loss"] for h in res.metrics_history]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--mode", default="inject", choices=engine_modes.list_modes())
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    base = get_config(args.arch, vocab_size=512) if args.full else \
        get_config(args.arch).reduced(vocab_size=512)

    print("== exact baseline ==")
    l_exact = train(base, args.steps, ckpt_dir=args.ckpt_dir)
    print(f"== approximate MLPs (mode={args.mode}, n=8, t=4) ==")
    l_approx = train(apply_approx(base, n=8, t=4, mode=args.mode), args.steps)

    k = max(5, args.steps // 10)
    print(f"\nfinal loss (mean of last {k}): "
          f"exact={np.mean(l_exact[-k:]):.4f}  approx={np.mean(l_approx[-k:]):.4f}  "
          f"gap={np.mean(l_approx[-k:]) - np.mean(l_exact[-k:]):+.4f}")
    print("-> the technique's accuracy cost at the training level; trade against "
          "the latency win quantified in benchmarks/latency_model.py")


if __name__ == "__main__":
    main()
