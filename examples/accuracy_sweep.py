"""Accuracy-configurability sweep: the paper's central knob.

For each splitting point t of an 8-bit multiplier, reports circuit-level
error metrics (paper Fig. 2), the analytic latency win (paper Fig. 3),
AND the end-task effect: perplexity of a small trained LM evaluated with
its MLPs quantized through the approximate multiplier at that t.

  PYTHONPATH=src python examples/accuracy_sweep.py --steps 80
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.latency_model import ripple_delay, segmented_delay  # noqa: E402
from repro.configs.base import TrainConfig
from repro.configs.registry import apply_approx, get_config
from repro.core import error_metrics
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.registry import build_model
from repro.train.steps import init_train_state, loss_fn, make_train_step

N = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()

    # ---- train a small exact model once
    cfg = get_config("qwen3-0.6b").reduced(vocab_size=256)
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=10, total_steps=args.steps)
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=0)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8))
    for i in range(args.steps):
        state, m = step(state, {k: jnp.asarray(v) for k, v in data.batch(i).items()})
    print(f"trained exact model: loss={float(m['loss']):.4f}\n")

    # ---- evaluate through the approximate multiplier at each t
    eval_batch = {k: jnp.asarray(v) for k, v in data.batch(10_000).items()}
    print(f"{'t':>2} {'ER':>7} {'NMED':>10} {'latency_win%':>13} {'eval_loss':>10}")
    for t in [None, 1, 2, 3, 4, 5, 6, 7]:
        if t is None:
            acfg, er, nmed, win = cfg, 0.0, 0.0, 0.0
        else:
            acfg = apply_approx(cfg, n=N, t=t, mode="bitexact")
            rep = error_metrics.exhaustive_eval(N, t)
            er, nmed = rep.er, rep.nmed
            win = 100 * (1 - segmented_delay(N, t) / ripple_delay(N))
        amodel = build_model(acfg)
        loss, _ = jax.jit(lambda p, b: loss_fn(p, b, jax.random.PRNGKey(1), amodel))(
            state.params, eval_batch)
        label = "exact" if t is None else str(t)
        print(f"{label:>2} {er:7.3f} {nmed:10.2e} {win:13.1f} {float(loss):10.4f}")


if __name__ == "__main__":
    main()
