"""Quickstart: the accuracy-configurable sequential multiplier in 5 minutes.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import error_metrics, error_model, seqmul

N, T = 8, 4  # 8-bit operands, carry chain split after bit 4

# ---- 1. a single approximate product --------------------------------------
a, b = np.uint32(183), np.uint32(201)
exact = int(a) * int(b)
words = seqmul.seq_mul_words(a, b, n=N, t=T, approx=True)
approx = int(seqmul.assemble_product_u64(words, n=N, t=T))
print(f"{int(a)} x {int(b)} = {exact} (exact)  {approx} (segmented, t={T})  "
      f"ED={exact - approx}")

# ---- 2. error metrics across the whole input space (paper Fig. 2) ---------
rep = error_metrics.exhaustive_eval(N, T, fix_to_1=False)
print(rep.summary())
print(f"closed-form MAE (Eq. 11) = {error_model.mae_closed_form(N, T)} "
      f"== measured worst overshoot {-rep.max_ed_neg}")

# ---- 3. accuracy is configurable via the splitting point t ----------------
for t in (2, 4, 6):
    r = error_metrics.exhaustive_eval(N, t)
    print(f"  t={t}: ER={r.er:.3f} NMED={r.nmed:.2e}  "
          f"(latency ~ max(t, n-t) = {max(t, N - t)} FA delays)")

# ---- 4. the multiplier as a GEMM inside a JAX model ------------------------
# repro.engine is the one dispatch layer: pick a mode from the registry,
# and the backend (reference jnp / Pallas kernels) is auto-selected.
print(f"engine modes: {engine.list_modes()}  backends: {list(engine.BACKENDS)}")
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
w = jnp.asarray(rng.standard_normal((64, 16)), jnp.float32)
y_exact = x @ w
y_approx = engine.matmul(x, w, n=N, t=T, mode="bitexact")
rel = float(jnp.abs(y_approx - y_exact).mean() / jnp.abs(y_exact).mean())
print(f"approximate GEMM rel. error vs exact: {rel:.3%}")

# ---- 5. the Pallas kernel path (interpret mode on CPU) ---------------------
am = jnp.asarray(rng.integers(0, 1 << N, (8, 128)), jnp.uint32)
bm = jnp.asarray(rng.integers(0, 1 << N, (8, 128)), jnp.uint32)
prod = engine.multiply(am, bm, n=N, t=T, backend="pallas")
print(f"Pallas elementwise approximate products: shape={prod.shape}, "
      f"dtype={prod.dtype}, finite={bool(jnp.isfinite(prod.astype(jnp.float32)).all())}")
