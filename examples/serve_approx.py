"""Batched serving with approximate-multiplier MLPs: the inference-side
deployment of the paper's technique (prefill + decode with KV caches,
static continuous batching).  Thin wrapper over repro.launch.serve.

  PYTHONPATH=src python examples/serve_approx.py
"""

import sys

from repro.launch import serve

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "qwen3-0.6b", "--reduced",
                "--approx-mode", "lowrank", "--requests", "8", "--batch", "4",
                "--gen", "16"] + sys.argv[1:]
    serve.main()
