"""Audit orchestration: the mode × tier matrix, verdicts, and the report.

``audit_matrix`` runs the three passes (overflow/exactness intervals,
gather bounds, VMEM budget) over every registered Pallas-backed engine
mode at every tier-resolved split, plus the boundary configurations
where the derived bounds bind (seqmul n=12, packed-word n=15/16) and
the kernel-level adversarial contracts.  ``certified`` is the cached
per-(mode, n, t) verdict ``engine.config.resolve_t`` consults;
``require_certified`` is the dispatch-time gate behind
``REPRO_STATIC_AUDIT=1``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

from repro.analysis import contracts, vmem
from repro.analysis import interp as interp_mod
from repro.analysis.domain import Interval
from repro.analysis.interp import AuditPolicy, Finding, Interpreter
from repro.analysis.spec import TraceSpec

__all__ = [
    "AuditResult",
    "CertificationError",
    "audit_kernel",
    "audit_matrix",
    "certified",
    "certified_elementwise",
    "matrix_entries",
    "report",
    "require_certified",
]


class CertificationError(ValueError):
    """A kernel was about to run that the static audit did not certify."""


@dataclasses.dataclass
class AuditResult:
    """Outcome of the three passes over one traced configuration."""

    name: str
    family: str  # gemm | attention | elementwise | kernel
    mode: str
    n: int
    t: int
    certified: bool
    findings: list[Finding]
    facts: dict[str, Any]
    vmem: list[dict]
    error: Optional[str] = None  # trace-time rejection (eager guard)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "family": self.family,
            "mode": self.mode,
            "n": self.n,
            "t": self.t,
            "certified": self.certified,
            "findings": [
                {"kind": f.kind, "message": f.message, "where": f.where,
                 "gating": f.gating}
                for f in self.findings
            ],
            "facts": dict(self.facts),
            "vmem": list(self.vmem),
            "error": self.error,
        }


def audit_kernel(spec: TraceSpec, *, family: str = "kernel", mode: str = "",
                 n: int = 0, t: int = 0) -> AuditResult:
    """Trace ``spec`` once and run all three passes over the jaxpr.

    A trace-time exception (an eager dispatch guard firing) is itself a
    static rejection: the configuration cannot launch, so the result is
    uncertified with the guard's message as the finding.
    """
    try:
        closed = spec.trace()
    except Exception as e:  # noqa: BLE001 - guard messages vary by kernel
        return AuditResult(
            name=spec.name, family=family, mode=mode, n=n, t=t,
            certified=False,
            findings=[Finding("trace-rejected", str(e))],
            facts={}, vmem=[], error=str(e),
        )
    policy = AuditPolicy(exact_products=spec.exact_products)
    it = Interpreter(policy)
    it.stack.append(spec.name)
    args = [Interval(r.lo, r.hi, int_valued=r.int_valued)
            for r in spec.input_ranges()]
    outs = it.run_closed(closed, args)
    findings = list(it.findings)
    findings.extend(interp_mod.check_output_contract(spec, outs))
    vm = vmem.estimate_pallas_calls(closed)
    for entry in vm:
        if not entry["within_budget"]:
            findings.append(Finding(
                "vmem-budget",
                f"pallas_call {entry['name']!r} needs "
                f"{entry['total_bytes'] / 2**20:.2f} MiB VMEM "
                f"({entry['pipeline_bytes'] / 2**20:.2f} blocks + "
                f"{entry['live_bytes'] / 2**20:.2f} live), over the "
                f"{entry['budget_bytes'] / 2**20:.0f} MiB budget",
                spec.name,
            ))
    ok = (not any(f.gating for f in findings)
          and all(e["within_budget"] for e in vm))
    return AuditResult(
        name=spec.name, family=family, mode=mode, n=n, t=t,
        certified=ok, findings=findings, facts=dict(it.facts), vmem=vm,
    )


# ------------------------------------------------------------- the matrix


def _tier_splits(n: int) -> list[int]:
    from repro.engine import config as engine_config

    ts = set()
    for name in engine_config.list_tiers():
        tier = engine_config.get_tier(name)
        for _target, budget in tier.budgets:
            ts.add(engine_config.resolve_t(n, budget).t)
    return sorted(ts)


def matrix_entries() -> list[tuple[str, str, int, int]]:
    """(family, mode, n, t) tuples covering the registered surface:
    every Pallas-backed GEMM mode at every tier-resolved split, the
    fused attention modes at every attn-budgeted tier split, the
    elementwise packed/two-word paths, the bound-frontier boundary
    configurations, and the kernel-level adversarial contracts."""
    from repro.engine import config as engine_config
    from repro.engine import modes as engine_modes

    n = engine_config.DEFAULT_N
    entries: list[tuple[str, str, int, int]] = []
    for mode in engine_modes.list_modes():
        if engine_modes.get_mode(mode).pallas is None:
            continue
        for t in _tier_splits(n):
            entries.append(("gemm", mode, n, t))
    # derived-bound frontier: widest seqmul the f32 assembly admits, and
    # the small-n tile branch
    entries.append(("gemm", "seqmul", 12, 6))
    entries.append(("gemm", "seqmul", 4, 2))
    for tname in engine_config.list_tiers():
        tier = engine_config.get_tier(tname)
        battn = dict(tier.budgets).get("attn")
        if battn is None:
            continue
        t_attn = engine_config.resolve_t(n, battn).t
        for amode in ("bitexact", "lowrank"):
            entries.append(("attention", amode, n, t_attn))
    t_def = engine_config.default_t(n)
    entries.append(("elementwise", "packed_single", n, t_def))
    entries.append(("elementwise", "packed_single", 15, 7))
    entries.append(("elementwise", "packed_words", 16, 8))
    entries.append(("kernel", "lut_gemm", n, t_def))
    entries.append(("kernel", "seqmul_gemm", 12, 6))
    seen: set[tuple] = set()
    out = []
    for e in entries:
        if e not in seen:
            seen.add(e)
            out.append(e)
    return out


def _build_spec(family: str, mode: str, n: int, t: int) -> TraceSpec | None:
    if family == "gemm":
        return contracts.gemm_trace(mode, n, t)
    if family == "attention":
        return contracts.attention_trace(mode, n, t)
    if family == "elementwise":
        return contracts.kernel_trace(mode, n, t)
    if family == "kernel":
        return contracts.kernel_trace(mode, n, t)
    raise ValueError(f"unknown audit family {family!r}")


def audit_matrix() -> list[AuditResult]:
    """Run the three passes over every matrix entry."""
    results = []
    for family, mode, n, t in matrix_entries():
        spec = _build_spec(family, mode, n, t)
        if spec is None:
            continue
        results.append(audit_kernel(spec, family=family, mode=mode, n=n, t=t))
    return results


def report() -> dict:
    """Machine-readable audit report (the CLI's ``--report`` payload)."""
    results = audit_matrix()
    return {
        "vmem_budget_bytes": vmem.VMEM_BUDGET_BYTES,
        "all_certified": all(r.certified for r in results),
        "entries": [r.to_dict() for r in results],
    }


# ------------------------------------------------------ cached verdicts


@functools.lru_cache(maxsize=4096)
def certified(mode: str, n: int, t: int) -> bool:
    """Static verdict for ``mode``'s GEMM at (n, t): True iff the traced
    kernel passes all three passes (trivially True for modes without a
    Pallas body — there is no kernel to certify).  This is what
    ``engine.config.resolve_t(..., mode=...)`` consults."""
    from repro.engine import modes as engine_modes

    spec = engine_modes.get_mode(mode)
    if spec.pallas is None:
        return True
    trace = contracts.gemm_trace(mode, n, t)
    if trace is None:
        return True
    return audit_kernel(trace, family="gemm", mode=mode, n=n, t=t).certified


@functools.lru_cache(maxsize=1024)
def certified_elementwise(n: int, t: int) -> bool:
    """Static verdict for the elementwise packed single-u32 kernel."""
    trace = contracts.kernel_trace("packed_single", n, t)
    return audit_kernel(trace, family="elementwise", mode="packed_single",
                        n=n, t=t).certified


def require_certified(mode: str, n: int, t: int, *,
                      elementwise: bool = False) -> None:
    """Dispatch-time gate (``REPRO_STATIC_AUDIT=1``): refuse to launch a
    kernel the analyzer has not certified."""
    ok = certified_elementwise(n, t) if elementwise else certified(mode, n, t)
    if not ok:
        raise CertificationError(
            f"static audit has not certified mode {mode!r} at (n={n}, t={t}) "
            f"and REPRO_STATIC_AUDIT=1 forbids launching unproven kernels; "
            f"run `python -m repro.launch.analyze` for the findings"
        )
