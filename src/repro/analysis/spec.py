"""Trace specifications: what to trace and under which input contract.

A :class:`TraceSpec` is the unit the auditor consumes: a callable plus
abstract input shapes and the *value contract* of each input (e.g. a
quantized magnitude plane is ``[0, 2^n - 1]`` and integer-valued, not
the full uint32 carrier range).  Kernel modules export colocated
``audit_trace_*`` builders returning these, so the contract lives next
to the code it describes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ValueRange:
    """Value contract for one traced input.

    ``lo``/``hi`` bound the elementwise values; ``int_valued`` asserts
    every element is a mathematical integer (regardless of carrier
    dtype — quantized magnitudes stored in f32 are still int-valued).
    """

    lo: float
    hi: float
    int_valued: bool = False

    @staticmethod
    def quantized(n: int) -> "ValueRange":
        """Magnitude plane of an n-bit quantizer: ``[0, 2^n - 1]``."""
        return ValueRange(0.0, float((1 << n) - 1), int_valued=True)

    @staticmethod
    def sign() -> "ValueRange":
        return ValueRange(-1.0, 1.0, int_valued=True)

    @staticmethod
    def carrier(dtype: Any) -> "ValueRange":
        """The full range representable by ``dtype`` (no contract)."""
        dt = jnp.dtype(dtype)
        if dt == jnp.dtype(jnp.bool_):
            return ValueRange(0.0, 1.0, int_valued=True)
        if jnp.issubdtype(dt, jnp.integer):
            info = jnp.iinfo(dt)
            return ValueRange(float(info.min), float(info.max), int_valued=True)
        return ValueRange(-math.inf, math.inf, int_valued=False)


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """One auditable trace: a callable, its abstract inputs, a contract.

    ``fn`` is traced with ``jax.make_jaxpr`` over ``args`` (which are
    ``jax.ShapeDtypeStruct``s or concrete arrays closed over as
    constants) — abstract eval only, nothing executes.  ``ranges`` maps
    positionally onto ``args``; ``None`` entries fall back to the
    carrier range of the arg dtype.
    """

    name: str
    fn: Callable[..., Any]
    args: Sequence[Any]
    ranges: Sequence[ValueRange | None] = ()
    # Whether integer-valued f32 intermediates must stay exactly
    # representable (< 2^24) *before* any reduction.  True for the
    # bit-exact parity contract (seqmul / LUT assembly); False for
    # float-valued paths (lowrank correction, fakequant).
    exact_products: bool = True
    # Output contracts: the caller-facing claim each traced output must
    # satisfy (positionally; None = unconstrained).  An output whose
    # derived envelope can leave its contract is a gating "contract"
    # finding — e.g. the packed single-u32 product is consumed as a
    # non-negative int32 LUT payload, so its contract is
    # ``[0, 2^31 - 1]``; the envelope leaves it exactly when 2n > 31.
    out_ranges: Sequence[ValueRange | None] = ()
    # Why each output contract holds/matters, for findings (optional).
    out_contract_reason: str = ""

    def trace(self) -> jax.core.ClosedJaxpr:
        return jax.make_jaxpr(self.fn)(*self.args)

    def input_ranges(self) -> list[ValueRange]:
        out: list[ValueRange] = []
        ranges = list(self.ranges) + [None] * (len(self.args) - len(self.ranges))
        for arg, rng in zip(self.args, ranges):
            if rng is not None:
                out.append(rng)
            else:
                out.append(ValueRange.carrier(arg.dtype))
        return out


def sds(shape: Sequence[int], dtype: Any) -> jax.ShapeDtypeStruct:
    """Shorthand for an abstract traced input."""
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))
