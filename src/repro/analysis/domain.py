"""The interval abstract domain the auditor interprets jaxprs over.

Every traced value is summarized by an :class:`Interval`: elementwise
bounds ``[lo, hi]`` plus two qualitative bits that carry the paper's
arithmetic contract through the dataflow —

``int_valued``
    every element is a mathematical integer.  Quantized magnitudes,
    split-word states and assembled products are int-valued even when
    their carrier dtype is f32; this is what lets the exactness pass
    distinguish "f32 used as a wide integer" from ordinary float math.

``reduced``
    the value has passed through a K-style reduction (``reduce_sum``,
    ``dot_general``, ``cumsum`` over a non-trivial axis).  Per-product
    assembly must stay under ``2^24`` for the bit-exact parity
    contract; *accumulator* envelopes scale with K and are reported as
    a derived fact (``k_exact``) rather than gated, matching the
    repo's parity model (docs/kernels.md).

``dominates``
    set of traced variables this value is a running elementwise upper
    bound of (seeded by ``reduce_max`` / ``max``).  The refinement
    ``exp(x - m) ∈ [0, 1]`` when ``m`` dominates ``x`` is what proves
    the online-softmax probabilities — and hence the ``U[p_int]``
    attention gather — in bounds.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, FrozenSet

import jax.numpy as jnp

# Largest integer magnitude exactly representable in f32 (2^24; every
# integer in [-2^24, 2^24] round-trips).  Products of n-bit magnitudes
# are < 2^{2n}, so per-product exactness holds iff 2n <= 24 — the
# seqmul ``n <= 12`` dispatch bound, rediscovered by the interpreter.
F32_EXACT_INT = float(1 << 24)

_INF = math.inf


def _carrier_bounds(dtype: Any) -> tuple[float, float]:
    try:
        dt = jnp.dtype(dtype)
    except TypeError:  # opaque dtypes (PRNG key<fry>) have no bounds
        return (-_INF, _INF)
    if dt == jnp.dtype(jnp.bool_):
        return (0.0, 1.0)
    if jnp.issubdtype(dt, jnp.integer):
        info = jnp.iinfo(dt)
        return (float(info.min), float(info.max))
    return (-_INF, _INF)


@dataclasses.dataclass(frozen=True)
class Interval:
    lo: float
    hi: float
    int_valued: bool = False
    reduced: bool = False
    dominates: FrozenSet[Any] = frozenset()

    def __post_init__(self) -> None:
        if self.lo > self.hi:  # pragma: no cover - domain invariant
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    # -- constructors ------------------------------------------------
    @staticmethod
    def point(v: float, int_valued: bool | None = None) -> "Interval":
        if int_valued is None:
            int_valued = float(v).is_integer()
        return Interval(float(v), float(v), int_valued=int_valued)

    @staticmethod
    def of_dtype(dtype: Any) -> "Interval":
        lo, hi = _carrier_bounds(dtype)
        try:
            int_valued = bool(jnp.issubdtype(jnp.dtype(dtype), jnp.integer)
                              or jnp.dtype(dtype) == jnp.dtype(jnp.bool_))
        except TypeError:
            int_valued = False
        return Interval(lo, hi, int_valued=int_valued)

    @staticmethod
    def bool01() -> "Interval":
        return Interval(0.0, 1.0, int_valued=True)

    # -- predicates --------------------------------------------------
    @property
    def is_point(self) -> bool:
        return self.lo == self.hi and math.isfinite(self.lo)

    def fits(self, dtype: Any) -> bool:
        lo, hi = _carrier_bounds(dtype)
        return self.lo >= lo and self.hi <= hi

    def magnitude(self) -> float:
        return max(abs(self.lo), abs(self.hi))

    # -- lattice -----------------------------------------------------
    def join(self, other: "Interval") -> "Interval":
        return Interval(
            min(self.lo, other.lo),
            max(self.hi, other.hi),
            int_valued=self.int_valued and other.int_valued,
            reduced=self.reduced or other.reduced,
            dominates=self.dominates & other.dominates,
        )

    # -- transfer helpers (plain data, no findings) ------------------
    def with_(self, **kw: Any) -> "Interval":
        return dataclasses.replace(self, **kw)


def join_all(ivals: list[Interval]) -> Interval:
    out = ivals[0]
    for iv in ivals[1:]:
        out = out.join(iv)
    return out


def _mul_bound(a: float, b: float) -> float:
    # inf * 0 in interval arithmetic is 0 (limits of products of bounds)
    if (a == 0.0 and math.isinf(b)) or (b == 0.0 and math.isinf(a)):
        return 0.0
    return a * b


def mul(a: Interval, b: Interval) -> Interval:
    cands = [
        _mul_bound(a.lo, b.lo),
        _mul_bound(a.lo, b.hi),
        _mul_bound(a.hi, b.lo),
        _mul_bound(a.hi, b.hi),
    ]
    return Interval(
        min(cands), max(cands),
        int_valued=a.int_valued and b.int_valued,
        reduced=a.reduced or b.reduced,
    )


def add(a: Interval, b: Interval) -> Interval:
    return Interval(
        a.lo + b.lo, a.hi + b.hi,
        int_valued=a.int_valued and b.int_valued,
        reduced=a.reduced or b.reduced,
    )


def sub(a: Interval, b: Interval) -> Interval:
    return Interval(
        a.lo - b.hi, a.hi - b.lo,
        int_valued=a.int_valued and b.int_valued,
        reduced=a.reduced or b.reduced,
    )


def div(a: Interval, b: Interval) -> Interval:
    if b.lo <= 0.0 <= b.hi:
        return Interval(-_INF, _INF, reduced=a.reduced or b.reduced)
    cands = [a.lo / b.lo, a.lo / b.hi, a.hi / b.lo, a.hi / b.hi]
    return Interval(min(cands), max(cands), int_valued=False,
                    reduced=a.reduced or b.reduced)


def min_(a: Interval, b: Interval) -> Interval:
    return Interval(min(a.lo, b.lo), min(a.hi, b.hi),
                    int_valued=a.int_valued and b.int_valued,
                    reduced=a.reduced or b.reduced)


def max_(a: Interval, b: Interval, dominated: FrozenSet[Any] = frozenset()) -> Interval:
    return Interval(max(a.lo, b.lo), max(a.hi, b.hi),
                    int_valued=a.int_valued and b.int_valued,
                    reduced=a.reduced or b.reduced,
                    dominates=a.dominates | b.dominates | dominated)


def shift_left(a: Interval, s: Interval) -> Interval:
    """Unclamped mathematical ``a * 2^s`` — overflow checked by caller."""
    if not (a.int_valued and s.int_valued) or s.lo < 0:
        return Interval(-_INF, _INF, int_valued=a.int_valued and s.int_valued)
    cands = [a.lo * 2.0 ** s.lo, a.lo * 2.0 ** s.hi,
             a.hi * 2.0 ** s.lo, a.hi * 2.0 ** s.hi]
    return Interval(min(cands), max(cands), int_valued=True,
                    reduced=a.reduced or s.reduced)


def shift_right(a: Interval, s: Interval) -> Interval:
    """Logical/arithmetic right shift: ``floor(a / 2^s)`` elementwise."""
    if s.lo < 0:
        return Interval(-_INF, _INF)
    cands = [math.floor(a.lo / 2.0 ** s.lo) if math.isfinite(a.lo) else a.lo,
             math.floor(a.lo / 2.0 ** s.hi) if math.isfinite(a.lo) else a.lo,
             math.floor(a.hi / 2.0 ** s.lo) if math.isfinite(a.hi) else a.hi,
             math.floor(a.hi / 2.0 ** s.hi) if math.isfinite(a.hi) else a.hi]
    return Interval(min(cands), max(cands), int_valued=True,
                    reduced=a.reduced or s.reduced)


def bit_and(a: Interval, b: Interval) -> Interval:
    """Sound envelope for ``a & b``: a non-negative mask bounds the result
    regardless of the other operand's sign (two's complement)."""
    if a.lo >= 0 and b.lo >= 0:
        return Interval(0.0, min(a.hi, b.hi), int_valued=True,
                        reduced=a.reduced or b.reduced)
    if a.lo >= 0:
        return Interval(0.0, a.hi, int_valued=True, reduced=a.reduced or b.reduced)
    if b.lo >= 0:
        return Interval(0.0, b.hi, int_valued=True, reduced=a.reduced or b.reduced)
    return Interval(-_INF, _INF, int_valued=a.int_valued and b.int_valued)


def _next_pow2_minus1(v: float) -> float:
    if not math.isfinite(v):
        return v
    if v <= 0:
        return 0.0
    return float((1 << int(v).bit_length()) - 1)


def bit_or(a: Interval, b: Interval, *, is_xor: bool = False) -> Interval:
    """Sound envelope for ``a | b`` / ``a ^ b`` on non-negative operands:
    the result never exceeds the sum (``a|b <= a+b``) and never needs
    more bits than the wider operand (``a|b < 2^bits(max(a, b))``).
    This tightness matters: the seqmul augend ``(s_lsp >> 1) |
    ((s_msp & 1) << (t-1))`` composes disjoint bit fields, and a
    doubling envelope would push the assembled n=12 product past 2^24
    when the true bound is exactly ``2^24 - 1``.  ``a ^ b`` shares the
    upper envelope but can cancel to 0, so its lower bound stays 0."""
    if a.lo >= 0 and b.lo >= 0:
        if math.isfinite(a.hi) and math.isfinite(b.hi):
            hi = min(a.hi + b.hi, _next_pow2_minus1(max(a.hi, b.hi)))
        else:
            hi = _INF
        lo = 0.0 if is_xor else max(a.lo, b.lo)
        return Interval(lo, hi,
                        int_valued=True, reduced=a.reduced or b.reduced)
    return Interval(-_INF, _INF, int_valued=a.int_valued and b.int_valued)


def monotone_unary(a: Interval, f: Any, int_valued: bool = False) -> Interval:
    def _apply(v: float) -> float:
        if not math.isfinite(v):
            return v if v > 0 else (f(-1e308) if v < 0 else v)
        try:
            return f(v)
        except OverflowError:
            return _INF

    lo, hi = _apply(a.lo), _apply(a.hi)
    if math.isnan(lo) or math.isnan(hi):
        return Interval(-_INF, _INF, reduced=a.reduced)
    return Interval(min(lo, hi), max(lo, hi), int_valued=int_valued,
                    reduced=a.reduced)
