"""Trace contracts for the registered engine surface.

Builds the :class:`~repro.analysis.spec.TraceSpec` set the audit matrix
runs over.  Engine-level GEMM traces go through the *real* dispatch
surface — ``ModeSpec.pallas`` with ``prepare``-built artifacts closed
over as constants — so the quantizer's clip is part of the traced
dataflow and magnitude bounds like ``[0, 2^n - 1]`` are *derived* from
the code, not asserted.  (This is what makes the LUT kernel's
gather-clamp provably redundant: the bound holds before the kernel is
entered.)

Kernel-level traces (the ``audit_trace*`` builders colocated in each
``repro.kernels`` module) deliberately bypass the public eager guards
so the dispatch bounds — seqmul ``n <= 12``, packed ``2n <= 31`` — can
be *rediscovered* by the interpreter instead of assumed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.spec import TraceSpec, ValueRange, sds
from repro.engine import config as engine_config
from repro.engine import modes as engine_modes

__all__ = ["gemm_trace", "attention_trace", "kernel_trace"]


def gemm_trace(mode: str, n: int, t: int) -> TraceSpec | None:
    """Engine-level trace of ``mode``'s Pallas GEMM body at (n, t).

    Returns ``None`` for modes without a fused kernel (their reference
    body runs on every backend — nothing to certify).  Inputs are
    unconstrained f32 operands shaped to put at least two steps on the
    K grid axis, so the revisited accumulator tile is exercised.
    """
    spec = engine_modes.get_mode(mode)
    if spec.pallas is None:
        return None
    tiles = engine_config.kernel_tiles(mode, n, t)
    p = engine_modes.GemmParams(
        n=n, t=t, fix_to_1=True, rank=8,
        tiles=(tiles.bm, tiles.bn, tiles.bk),
    )
    key = jax.random.PRNGKey(0)
    m_dim, k_dim, n_dim = tiles.bm, 2 * tiles.bk, tiles.bn

    def fn(x, w):
        extra = spec.prepare(x, w, p, key) if spec.prepare is not None else ()
        return spec.pallas(x, w, p, *extra)

    return TraceSpec(
        name=f"gemm:{mode}[n={n},t={t}]",
        fn=fn,
        args=[sds((m_dim, k_dim), jnp.float32), sds((k_dim, n_dim), jnp.float32)],
        ranges=[None, None],
        exact_products=spec.exact_products,
    )


def attention_trace(mode: str, n: int, t: int, *, seq: int = 256,
                    heads: int = 4, head_dim: int = 64,
                    rank: int = 8) -> TraceSpec:
    """Engine-level trace of the fused flash-attention forward at (n, t).

    At least two K-axis grid steps, causal masking on, GQA grouping 2:
    the online-softmax carry refs and the in-kernel ``U[p_int]`` /
    product-LUT gathers are all on the traced path.  Tiles are the
    mode's deployed defaults (``attn_tiles``) so the certificate covers
    exactly what dispatch launches.
    """
    from repro.kernels.approx_attention import _approx_fwd, attn_tiles

    bq, bk = attn_tiles(mode)
    seq = max(seq, 2 * bk, bq)
    kv = max(heads // 2, 1)

    def fn(q, k, v, q_pos, k_pos):
        return _approx_fwd(
            q, k, v, q_pos, k_pos, mode=mode, causal=True, window=None,
            softcap=None, scale=1.0, n=n, t=t, fix_to_1=True, rank=rank,
            bq=bq, bk=bk, interpret=True,
        )

    return TraceSpec(
        name=f"attention:{mode}[n={n},t={t}]",
        fn=fn,
        args=[
            sds((1, seq, heads, head_dim), jnp.float32),
            sds((1, seq, kv, head_dim), jnp.float32),
            sds((1, seq, kv, head_dim), jnp.float32),
            sds((1, seq), jnp.int32),
            sds((1, seq), jnp.int32),
        ],
        ranges=[
            None, None, None,
            ValueRange(0.0, float(seq - 1), int_valued=True),
            ValueRange(-1.0, float(seq - 1), int_valued=True),
        ],
        exact_products=engine_modes.get_mode(mode).exact_products,
    )


def kernel_trace(kind: str, n: int, t: int) -> TraceSpec:
    """Kernel-level trace under the kernel's *documented* input contract
    (quantized magnitudes in ``[0, 2^n - 1]``), bypassing eager guards —
    the bound-derivation surface.  ``kind`` is one of ``seqmul_gemm``,
    ``lut_gemm``, ``packed_single``, ``packed_words``, ``packed_gemm``,
    ``lowrank_gemm``."""
    from repro.kernels import (
        lowrank_matmul,
        lut_matmul,
        packed_matmul,
        seqmul_kernel,
        seqmul_matmul,
    )

    builders = {
        "seqmul_gemm": seqmul_matmul.audit_trace,
        "lut_gemm": lut_matmul.audit_trace,
        "packed_single": seqmul_kernel.audit_trace_packed,
        "packed_words": seqmul_kernel.audit_trace_words,
        "packed_gemm": packed_matmul.audit_trace,
        "lowrank_gemm": lowrank_matmul.audit_trace,
    }
    try:
        builder = builders[kind]
    except KeyError:
        raise ValueError(
            f"unknown kernel trace kind {kind!r}; known: {sorted(builders)}"
        ) from None
    return builder(n=n, t=t)
