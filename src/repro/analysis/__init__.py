"""Static analysis of the accuracy-configurable kernels (the jaxpr auditor).

The paper's segmented-carry design gives every intermediate a *known
algebraic bit-width* (t-bit LSP words, deferred carries of weight 2^t,
2n-bit products).  This package turns those algebraic facts into
*checked* facts: every registered engine mode's kernel body is traced to
a jaxpr (abstract eval only — nothing executes) and audited by three
passes:

``overflow``  interval abstract interpretation over the integer
              dataflow (`repro.analysis.interp`), proving no
              intermediate wraps its carrier dtype and no per-product
              integer-valued f32 leaves the exactly-representable range
              — the ``2n <= 31`` packed bound and the ``n <= 12``
              seqmul bound fall out as *derived* facts.
``gather``    bounds checking of every LUT / embedding gather index
              against its table extent, end to end from the quantizer's
              clamp — the PR 6 VMEM-gather clamp becomes provably
              redundant instead of load-bearing.
``vmem``      per-(mode, n, t, tiles) VMEM budget estimation from the
              `pallas_call` BlockSpecs plus a peak-liveness walk of the
              kernel jaxpr (`repro.analysis.vmem`) — the machine-
              readable source of the docs/kernels.md sizing table.

`repro.analysis.audit` orchestrates the passes over the registered
mode × quality-tier matrix; ``launch/analyze.py`` is the CLI and the
gating CI entry point; ``engine.config.resolve_t`` consults
:func:`certified` so the controller cannot resolve an (n, t) the
kernels cannot legally execute.
"""

from repro.analysis.audit import (
    AuditResult,
    audit_kernel,
    audit_matrix,
    certified,
    matrix_entries,
    report,
    require_certified,
)
from repro.analysis.domain import F32_EXACT_INT, Interval
from repro.analysis.interp import AuditPolicy, Finding, interpret
from repro.analysis.spec import TraceSpec, ValueRange
from repro.analysis.vmem import (
    VMEM_BUDGET_BYTES,
    TileBudgetError,
    tile_footprint,
    validate_tiles,
)

__all__ = [
    "AuditPolicy",
    "AuditResult",
    "F32_EXACT_INT",
    "Finding",
    "Interval",
    "TileBudgetError",
    "TraceSpec",
    "VMEM_BUDGET_BYTES",
    "ValueRange",
    "audit_kernel",
    "audit_matrix",
    "certified",
    "interpret",
    "matrix_entries",
    "report",
    "require_certified",
    "tile_footprint",
    "validate_tiles",
]
