"""Interval abstract interpretation of jaxprs (the overflow/gather passes).

Walks a traced :class:`jax.core.ClosedJaxpr` with every value summarized
by a :class:`repro.analysis.domain.Interval` — O(1) work per equation
regardless of tensor shape, so auditing realistic kernel envelopes is
cheap.  Three families of checks fire as equations are interpreted:

* **carrier overflow** — an integer-dtype result whose mathematical
  envelope leaves its carrier range.  Signed shifts are treated as
  defined-modular (the packed kernel's ``(w << 16) >> 16`` lane
  extraction is intentional); *unsigned* wraparound is a finding.
  Output *contracts* (:func:`check_output_contract`) extend this to
  caller-facing claims that bind before any carrier wraps — the packed
  product tops out at ``2^{2n} - 1`` (inside uint32 even at n=16) but
  its int32-payload contract breaks there, rediscovering ``2n <= 31``.
* **f32 exactness** — an integer-valued float32 whose *pre-reduction*
  magnitude exceeds ``2^24`` cannot represent every integer it may
  take, breaking the bit-exact parity contract.  Assembled seqmul
  products are ``< 2^{2n}``, so this rediscovers the ``n <= 12``
  seqmul bound.  Reduction *accumulators* scale with K and are
  reported as a derived ``k_exact`` envelope instead of gated,
  matching the parity model in docs/kernels.md.
* **gather bounds** — every ``gather`` index interval must lie inside
  ``[0, dim - slice]`` of its table.  The online-softmax probabilities
  are proven in ``[0, 1]`` via a dominance refinement (``reduce_max``
  results dominate their operand; ``exp(x - m) <= 1`` when ``m``
  dominates ``x``), which closes the ``U[p_int]`` attention gather.

``pallas_call`` is interpreted by modeling kernel refs as mutable
cells: input refs start at the outer operand interval, output and
scratch refs start uninitialized, writes *join* into the cell (sound
for revisited accumulator tiles).  The innermost grid axis — the K
revisit axis in every GEMM kernel here — is unrolled with a precise
``program_id``, so ``k == 0`` initialization branches resolve exactly.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import numpy as np

from repro.analysis import domain
from repro.analysis.domain import F32_EXACT_INT, Interval
from repro.analysis.spec import TraceSpec

_INF = math.inf

# Finding kinds that block certification.  "note" is informational;
# "unknown" is gating because an unmodeled primitive means the proof
# does not cover the kernel.
GATING_KINDS = frozenset(
    {"overflow", "exactness", "gather", "unknown", "vmem-budget",
     "trace-rejected", "contract"})


# f32 arithmetic whose mathematical result may not be representable;
# everything else (rounding, clamping, selection, structural ops) only
# produces values that are representable by construction.
_EXACTNESS_PRIMS = frozenset({"mul", "add", "sub", "dot_general"})


@dataclasses.dataclass(frozen=True)
class Finding:
    kind: str
    message: str
    where: str = ""

    @property
    def gating(self) -> bool:
        return self.kind in GATING_KINDS


@dataclasses.dataclass(frozen=True)
class AuditPolicy:
    # Gate unreduced integer-valued f32 values above 2^24 (bit-exact
    # parity contract).  Off for float-valued modes (lowrank/fakequant).
    exact_products: bool = True
    # Unroll caps; exceeding them widens (sound, less precise).
    grid_cap: int = 64
    scan_cap: int = 128
    while_cap: int = 64


@dataclasses.dataclass
class InterpReport:
    findings: list[Finding]
    facts: dict[str, Any]

    @property
    def gating_findings(self) -> list[Finding]:
        return [f for f in self.findings if f.gating]

    @property
    def certified(self) -> bool:
        return not self.gating_findings


class _RefCell:
    """Mutable abstract state of one pallas ref (None = uninitialized)."""

    __slots__ = ("av", "dtype")

    def __init__(self, dtype: Any, av: Interval | None = None):
        self.av = av
        self.dtype = dtype

    def read(self) -> Interval:
        return self.av if self.av is not None else Interval.of_dtype(self.dtype)

    def write(self, val: Interval) -> None:
        # Dominance claims reference jaxpr vars of the *current* unrolled
        # step; a value read back on a later step must not carry them
        # (the same vars will hold different values there).
        val = val.with_(dominates=frozenset())
        self.av = val if self.av is None else self.av.join(val)


def _const_interval(c: Any) -> Interval:
    arr = np.asarray(c)
    if arr.size == 0:
        return Interval.point(0.0)
    if arr.dtype == np.bool_:
        return Interval(float(arr.min()), float(arr.max()), int_valued=True)
    lo, hi = float(arr.min()), float(arr.max())
    int_valued = np.issubdtype(arr.dtype, np.integer)
    if not int_valued and arr.size <= (1 << 22) and np.all(np.isfinite(arr)):
        # Integrality above 2^24 is vacuous for floats (every
        # representable f32 there is an integer) and would make mask
        # sentinels like -2.38e38 look like wide-integer arithmetic.
        int_valued = bool(np.all(np.mod(arr, 1.0) == 0.0)
                          and max(abs(lo), abs(hi)) <= F32_EXACT_INT)
    return Interval(lo, hi, int_valued=int_valued)


def _clamp_to(iv: Interval, dtype: Any) -> Interval:
    full = Interval.of_dtype(dtype)
    lo = max(iv.lo, full.lo)
    hi = min(iv.hi, full.hi)
    if lo > hi:  # envelope entirely out of carrier: wraps to full range
        return full
    return Interval(lo, hi, int_valued=iv.int_valued or full.int_valued,
                    reduced=iv.reduced, dominates=iv.dominates)


def _is_integer_dtype(dtype: Any) -> bool:
    import jax.numpy as jnp

    dt = jnp.dtype(dtype)
    return jnp.issubdtype(dt, jnp.integer)


def _is_unsigned_dtype(dtype: Any) -> bool:
    import jax.numpy as jnp

    return jnp.issubdtype(jnp.dtype(dtype), jnp.unsignedinteger)


def _is_f32(dtype: Any) -> bool:
    import jax.numpy as jnp

    return jnp.dtype(dtype) == jnp.dtype(jnp.float32)


def _point_f32_exact(iv: Interval) -> bool:
    """A point interval whose single value round-trips through f32 is
    exactly representable no matter its magnitude (e.g. the causal-mask
    fill constant, a large integral f32 literal)."""
    return iv.is_point and float(np.float32(iv.lo)) == iv.lo


class Interpreter:
    def __init__(self, policy: AuditPolicy):
        self.policy = policy
        self.findings: list[Finding] = []
        self.facts: dict[str, Any] = {
            "gathers_checked": 0,
            "gathers_proven": 0,
            "k_exact": None,
            "max_unreduced_int_f32": 0.0,
        }
        self.stack: list[str] = []

    # -- bookkeeping -------------------------------------------------
    def _where(self) -> str:
        return "/".join(self.stack)

    def _finding(self, kind: str, message: str) -> None:
        self.findings.append(Finding(kind, message, self._where()))

    def _note_k_exact(self, per_term_mag: float) -> None:
        if per_term_mag <= 0 or not math.isfinite(per_term_mag):
            return
        k = int(F32_EXACT_INT // max(1.0, per_term_mag))
        prev = self.facts["k_exact"]
        self.facts["k_exact"] = k if prev is None else min(prev, k)

    # -- environment -------------------------------------------------
    def _read(self, env: dict, atom: Any) -> Any:
        if isinstance(atom, jax.core.Literal):
            return _const_interval(atom.val)
        return env[atom]

    def _land(self, env: dict, eqn: Any, outvar: Any, iv: Interval) -> None:
        """Bind an equation result, running the overflow/exactness checks."""
        aval = outvar.aval
        dtype = getattr(aval, "dtype", None)
        if dtype is None:
            env[outvar] = iv
            return
        if _is_integer_dtype(dtype):
            if not iv.fits(dtype):
                # Signed left shifts are defined-modular lane surgery
                # here ((w << 16) >> 16); bitwise ops are closed over
                # their carrier, so an out-of-carrier envelope on them
                # is domain imprecision, never a semantic overflow.
                exempt = (eqn.primitive.name in ("or", "and", "xor", "not")
                          or (eqn.primitive.name == "shift_left"
                              and not _is_unsigned_dtype(dtype)))
                if not exempt:
                    self._finding(
                        "overflow",
                        f"{eqn.primitive.name}: envelope [{iv.lo:.6g}, {iv.hi:.6g}] "
                        f"leaves {np.dtype(dtype).name} carrier range",
                    )
                iv = _clamp_to(iv, dtype)
        elif _is_f32(dtype) and iv.int_valued and not iv.reduced:
            mag = iv.magnitude()
            if math.isfinite(mag):
                self.facts["max_unreduced_int_f32"] = max(
                    self.facts["max_unreduced_int_f32"], mag)
            # Only value-constructing arithmetic can silently round: a
            # round/floor/ceil result is representable by construction
            # (every f32 >= 2^24 is already an integer), and joins/
            # selections only repeat already-checked values.
            constructs = eqn.primitive.name in _EXACTNESS_PRIMS
            if (constructs and self.policy.exact_products
                    and mag > F32_EXACT_INT and not _point_f32_exact(iv)):
                self._finding(
                    "exactness",
                    f"{eqn.primitive.name}: integer-valued f32 envelope "
                    f"[{iv.lo:.6g}, {iv.hi:.6g}] exceeds exactly-representable "
                    f"2^24 before any reduction",
                )
                iv = iv.with_(int_valued=False)
        env[outvar] = iv

    # -- jaxpr walk --------------------------------------------------
    def run_closed(self, closed: jax.core.ClosedJaxpr, args: list[Any]) -> list[Any]:
        consts = [_const_interval(c) for c in closed.consts]
        return self.run(closed.jaxpr, consts, args)

    def run(self, jaxpr: Any, consts: list[Any], args: list[Any]) -> list[Any]:
        env: dict[Any, Any] = {}
        for v, c in zip(jaxpr.constvars, consts):
            env[v] = c
        for v, a in zip(jaxpr.invars, args):
            env[v] = a
        for eqn in jaxpr.eqns:
            self.eqn(env, eqn)
        return [self._read(env, v) for v in jaxpr.outvars]

    def eqn(self, env: dict, eqn: Any) -> None:
        name = eqn.primitive.name
        handler = _HANDLERS.get(name)
        if handler is not None:
            handler(self, env, eqn)
            return
        self._finding(
            "unknown",
            f"primitive {name!r} is not modeled by the auditor",
        )
        for ov in eqn.outvars:
            dtype = getattr(ov.aval, "dtype", None)
            env[ov] = Interval.of_dtype(dtype) if dtype is not None else Interval(-_INF, _INF)

    # -- sub-jaxpr descent -------------------------------------------
    def _descend(self, closed: Any, args: list[Any], tag: str) -> list[Any]:
        self.stack.append(tag)
        try:
            if hasattr(closed, "consts"):
                outs = self.run_closed(closed, args)
            else:
                outs = self.run(closed, [], args)
        finally:
            self.stack.pop()
        # Dominance sets name sub-jaxpr-local vars; strip them at the
        # boundary (also breaks stale claims across scan iterations,
        # where the same body vars rebind to new values).
        return [o.with_(dominates=frozenset()) if isinstance(o, Interval) else o
                for o in outs]


def check_output_contract(spec: TraceSpec, outs: list[Any]) -> list[Finding]:
    """Check traced output envelopes against the spec's ``out_ranges``.

    The contract is the *caller-facing claim* about the kernel's result
    (e.g. "the packed product is a non-negative int32 payload"); an
    envelope that can leave it is a gating finding even when no carrier
    dtype wraps — this is how the packed ``2n <= 31`` bound is
    rediscovered, since the packed word tops out at ``2^{2n} - 1`` and
    first exceeds the int32 payload contract at ``n = 16``.
    """
    findings: list[Finding] = []
    for i, (out, rng) in enumerate(zip(outs, spec.out_ranges)):
        if rng is None or not isinstance(out, Interval):
            continue
        if out.lo < rng.lo or out.hi > rng.hi:
            why = f" ({spec.out_contract_reason})" if spec.out_contract_reason else ""
            findings.append(Finding(
                "contract",
                f"output {i} envelope [{out.lo:.6g}, {out.hi:.6g}] can leave "
                f"its declared contract [{rng.lo:.6g}, {rng.hi:.6g}]{why}",
                spec.name,
            ))
    return findings


def interpret(spec: TraceSpec, policy: AuditPolicy | None = None) -> InterpReport:
    """Trace ``spec`` and abstractly interpret it under its contract."""
    if policy is None:
        policy = AuditPolicy(exact_products=spec.exact_products)
    closed = spec.trace()
    args = [
        Interval(r.lo, r.hi, int_valued=r.int_valued)
        for r in spec.input_ranges()
    ]
    it = Interpreter(policy)
    it.stack.append(spec.name)
    outs = it.run_closed(closed, args)
    it.findings.extend(check_output_contract(spec, outs))
    return InterpReport(findings=it.findings, facts=it.facts)


def interpret_closed(
    closed: jax.core.ClosedJaxpr,
    args: list[Interval],
    policy: AuditPolicy | None = None,
) -> InterpReport:
    it = Interpreter(policy or AuditPolicy())
    it.run_closed(closed, args)
    return InterpReport(findings=it.findings, facts=it.facts)


# ---------------------------------------------------------------------
# primitive handlers
# ---------------------------------------------------------------------

_HANDLERS: dict[str, Callable[[Interpreter, dict, Any], None]] = {}


def _register(*names: str):
    def deco(fn):
        for n in names:
            _HANDLERS[n] = fn
        return fn

    return deco


def _in(self: Interpreter, env: dict, eqn: Any) -> list[Any]:
    return [self._read(env, a) for a in eqn.invars]


def _unary_identity(self, env, eqn):
    (a,) = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0], a)


_register("copy", "stop_gradient", "reduce_precision", "real")(_unary_identity)


@_register("broadcast_in_dim", "reshape", "squeeze", "expand_dims")
def _structural(self, env, eqn):
    (a, *_rest) = _in(self, env, eqn)
    # elementwise-identical: dominance survives
    self._land(env, eqn, eqn.outvars[0], a)


@_register("transpose", "rev", "slice", "dynamic_slice")
def _permute(self, env, eqn):
    a = self._read(env, eqn.invars[0])
    self._land(env, eqn, eqn.outvars[0], a.with_(dominates=frozenset()))


@_register("concatenate")
def _concat(self, env, eqn):
    ivs = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0], domain.join_all(ivs))


@_register("pad")
def _pad(self, env, eqn):
    op, padval = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0], op.join(padval))


@_register("dynamic_update_slice")
def _dus(self, env, eqn):
    op, upd, *_idx = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0], op.join(upd))


@_register("iota")
def _iota(self, env, eqn):
    dim = eqn.params["dimension"]
    shape = eqn.params["shape"]
    hi = max(0, shape[dim] - 1)
    self._land(env, eqn, eqn.outvars[0], Interval(0.0, float(hi), int_valued=True))


@_register("add")
def _add(self, env, eqn):
    a, b = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0], domain.add(a, b))


@_register("sub")
def _sub(self, env, eqn):
    a, b = _in(self, env, eqn)
    out = domain.sub(a, b)
    # dominance refinement: if b is a running max over a, then a - b <= 0
    a_var = eqn.invars[0]
    if not isinstance(a_var, jax.core.Literal) and a_var in b.dominates:
        out = Interval(min(out.lo, 0.0), min(out.hi, 0.0),
                       int_valued=out.int_valued, reduced=out.reduced)
    self._land(env, eqn, eqn.outvars[0], out)


@_register("mul")
def _mul(self, env, eqn):
    a, b = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0], domain.mul(a, b))


@_register("div")
def _div(self, env, eqn):
    a, b = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0], domain.div(a, b))


@_register("rem")
def _rem(self, env, eqn):
    a, b = _in(self, env, eqn)
    m = b.magnitude()
    if a.lo >= 0:
        out = Interval(0.0, min(a.hi, m), int_valued=a.int_valued and b.int_valued)
    else:
        out = Interval(-m, m, int_valued=a.int_valued and b.int_valued)
    self._land(env, eqn, eqn.outvars[0], out)


@_register("max")
def _max(self, env, eqn):
    a, b = _in(self, env, eqn)
    dominated = frozenset(
        v for v in eqn.invars if not isinstance(v, jax.core.Literal))
    self._land(env, eqn, eqn.outvars[0], domain.max_(a, b, dominated))


@_register("min")
def _min(self, env, eqn):
    a, b = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0], domain.min_(a, b))


@_register("neg")
def _neg(self, env, eqn):
    (a,) = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0],
               Interval(-a.hi, -a.lo, int_valued=a.int_valued, reduced=a.reduced))


@_register("abs")
def _abs(self, env, eqn):
    (a,) = _in(self, env, eqn)
    if a.lo >= 0:
        out = a.with_(dominates=frozenset())
    elif a.hi <= 0:
        out = Interval(-a.hi, -a.lo, int_valued=a.int_valued, reduced=a.reduced)
    else:
        out = Interval(0.0, a.magnitude(), int_valued=a.int_valued, reduced=a.reduced)
    self._land(env, eqn, eqn.outvars[0], out)


@_register("sign")
def _sign(self, env, eqn):
    (a,) = _in(self, env, eqn)
    lo = -1.0 if a.lo < 0 else 0.0 if a.lo == 0 else 1.0
    hi = 1.0 if a.hi > 0 else 0.0 if a.hi == 0 else -1.0
    self._land(env, eqn, eqn.outvars[0], Interval(lo, hi, int_valued=True))


@_register("floor")
def _floor(self, env, eqn):
    (a,) = _in(self, env, eqn)
    lo = math.floor(a.lo) if math.isfinite(a.lo) else a.lo
    hi = math.floor(a.hi) if math.isfinite(a.hi) else a.hi
    self._land(env, eqn, eqn.outvars[0],
               Interval(lo, hi, int_valued=True, reduced=a.reduced))


@_register("ceil", "round")
def _round(self, env, eqn):
    (a,) = _in(self, env, eqn)
    lo = math.floor(a.lo) if math.isfinite(a.lo) else a.lo
    hi = math.ceil(a.hi) if math.isfinite(a.hi) else a.hi
    self._land(env, eqn, eqn.outvars[0],
               Interval(lo, hi, int_valued=True, reduced=a.reduced))


@_register("clamp")
def _clamp(self, env, eqn):
    lo_iv, x, hi_iv = _in(self, env, eqn)
    lo = max(x.lo, lo_iv.lo)
    hi = min(x.hi, hi_iv.hi)
    if lo > hi:
        lo, hi = lo_iv.lo, hi_iv.hi
    self._land(env, eqn, eqn.outvars[0],
               Interval(lo, hi,
                        int_valued=x.int_valued and lo_iv.int_valued and hi_iv.int_valued,
                        reduced=x.reduced))


@_register("integer_pow")
def _integer_pow(self, env, eqn):
    (a,) = _in(self, env, eqn)
    y = eqn.params["y"]
    cands = [a.lo ** y, a.hi ** y]
    if y % 2 == 0 and a.lo < 0 < a.hi:
        cands.append(0.0)
    self._land(env, eqn, eqn.outvars[0],
               Interval(min(cands), max(cands), int_valued=a.int_valued and y >= 0,
                        reduced=a.reduced))


def _erf_inv(v: float) -> float:
    """Monotone inverse of ``math.erf`` by bisection (interval endpoints
    only — precision well beyond what an envelope needs)."""
    lo, hi = -10.0, 10.0
    for _ in range(80):
        mid = (lo + hi) / 2
        if math.erf(mid) < v:
            lo = mid
        else:
            hi = mid
    return (lo + hi) / 2


def _monotone(fn):
    def handler(self, env, eqn):
        (a, *_rest) = _in(self, env, eqn)
        self._land(env, eqn, eqn.outvars[0], domain.monotone_unary(a, fn))

    return handler


_register("exp")(_monotone(math.exp))
_register("exp2")(_monotone(lambda v: 2.0 ** v))
_register("log")(_monotone(lambda v: math.log(v) if v > 0 else -_INF))
_register("log1p")(_monotone(lambda v: math.log1p(v) if v > -1 else -_INF))
_register("expm1")(_monotone(math.expm1))
_register("tanh")(_monotone(math.tanh))
_register("logistic")(_monotone(lambda v: 1.0 / (1.0 + math.exp(-min(v, 700.0)))))
_register("erf")(_monotone(math.erf))
_register("erf_inv")(_monotone(lambda v: -_INF if v <= -1 else _INF if v >= 1 else
                               _erf_inv(v)))
_register("sqrt")(_monotone(lambda v: math.sqrt(v) if v >= 0 else 0.0))
_register("rsqrt")(_monotone(lambda v: 1.0 / math.sqrt(v) if v > 0 else _INF))


@_register("shift_left")
def _shift_left(self, env, eqn):
    a, s = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0], domain.shift_left(a, s))


@_register("shift_right_logical", "shift_right_arithmetic")
def _shift_right(self, env, eqn):
    a, s = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0], domain.shift_right(a, s))


def _is_bool(atom) -> bool:
    import jax.numpy as jnp

    return jnp.dtype(atom.aval.dtype) == jnp.dtype(jnp.bool_)


@_register("and")
def _and(self, env, eqn):
    a, b = _in(self, env, eqn)
    if _is_bool(eqn.outvars[0]):
        if a.is_point and b.is_point:
            out = Interval.point(float(bool(a.lo) and bool(b.lo)))
        else:
            out = Interval.bool01()
    else:
        out = domain.bit_and(a, b)
    self._land(env, eqn, eqn.outvars[0], out)


@_register("or", "xor")
def _or(self, env, eqn):
    a, b = _in(self, env, eqn)
    if _is_bool(eqn.outvars[0]):
        out = Interval.bool01()
        if a.is_point and b.is_point:
            av, bv = bool(a.lo), bool(b.lo)
            out = Interval.point(
                float(av or bv if eqn.primitive.name == "or" else av != bv))
    else:
        out = domain.bit_or(a, b, is_xor=eqn.primitive.name == "xor")
    self._land(env, eqn, eqn.outvars[0], out)


@_register("not")
def _not(self, env, eqn):
    (a,) = _in(self, env, eqn)
    if _is_bool(eqn.outvars[0]):
        out = (Interval.point(float(not bool(a.lo))) if a.is_point
               else Interval.bool01())
    else:
        out = Interval.of_dtype(eqn.outvars[0].aval.dtype)
    self._land(env, eqn, eqn.outvars[0], out)


def _cmp(self, env, eqn, certain_true, certain_false):
    a, b = _in(self, env, eqn)
    if certain_true(a, b):
        out = Interval.point(1.0)
    elif certain_false(a, b):
        out = Interval.point(0.0)
    else:
        out = Interval.bool01()
    self._land(env, eqn, eqn.outvars[0], out)


_register("eq")(lambda s, e, q: _cmp(
    s, e, q,
    lambda a, b: a.is_point and b.is_point and a.lo == b.lo,
    lambda a, b: a.hi < b.lo or b.hi < a.lo))
_register("ne")(lambda s, e, q: _cmp(
    s, e, q,
    lambda a, b: a.hi < b.lo or b.hi < a.lo,
    lambda a, b: a.is_point and b.is_point and a.lo == b.lo))
_register("lt")(lambda s, e, q: _cmp(
    s, e, q, lambda a, b: a.hi < b.lo, lambda a, b: a.lo >= b.hi))
_register("le")(lambda s, e, q: _cmp(
    s, e, q, lambda a, b: a.hi <= b.lo, lambda a, b: a.lo > b.hi))
_register("gt")(lambda s, e, q: _cmp(
    s, e, q, lambda a, b: a.lo > b.hi, lambda a, b: a.hi <= b.lo))
_register("ge")(lambda s, e, q: _cmp(
    s, e, q, lambda a, b: a.lo >= b.hi, lambda a, b: a.hi < b.lo))


@_register("select_n")
def _select_n(self, env, eqn):
    pred, *cases = _in(self, env, eqn)
    if pred.is_point and 0 <= int(pred.lo) < len(cases):
        out = cases[int(pred.lo)]
    else:
        out = domain.join_all(cases)
    self._land(env, eqn, eqn.outvars[0], out)


@_register("convert_element_type")
def _convert(self, env, eqn):
    (a,) = _in(self, env, eqn)
    new_dtype = eqn.params["new_dtype"]
    out = a
    if _is_integer_dtype(new_dtype):
        if not a.int_valued:
            # float->int conversion truncates toward zero
            lo = math.floor(a.lo) if math.isfinite(a.lo) else a.lo
            hi = math.ceil(a.hi) if math.isfinite(a.hi) else a.hi
            out = Interval(lo, hi, int_valued=True, reduced=a.reduced)
        else:
            out = a.with_(int_valued=True, dominates=frozenset())
    else:
        # int->float: exactness of wide integers is checked here, since a
        # 2n-bit assembled product first becomes inexact at this cast.
        if (a.int_valued and not a.reduced and self.policy.exact_products
                and _is_f32(new_dtype) and a.magnitude() > F32_EXACT_INT
                and not _point_f32_exact(a)):
            self._finding(
                "exactness",
                f"convert_element_type: integer envelope [{a.lo:.6g}, {a.hi:.6g}] "
                f"is not exactly representable in float32 (> 2^24)",
            )
            out = a.with_(int_valued=False, dominates=frozenset())
        else:
            out = a.with_(dominates=a.dominates if _is_f32(new_dtype) else frozenset())
    self._land(env, eqn, eqn.outvars[0], out)


@_register("bitcast_convert_type")
def _bitcast(self, env, eqn):
    new_dtype = eqn.params["new_dtype"]
    self._land(env, eqn, eqn.outvars[0], Interval.of_dtype(new_dtype))


# -- reductions ------------------------------------------------------


def _axes_size(eqn, operand_index: int = 0) -> int:
    shape = eqn.invars[operand_index].aval.shape
    axes = eqn.params["axes"]
    n = 1
    for ax in axes:
        n *= shape[ax]
    return max(n, 1)


@_register("reduce_sum")
def _reduce_sum(self, env, eqn):
    (a,) = _in(self, env, eqn)
    n = _axes_size(eqn)
    out = Interval(a.lo * n, a.hi * n, int_valued=a.int_valued,
                   reduced=a.reduced or n > 1)
    if n > 1 and a.int_valued and _is_f32(eqn.invars[0].aval.dtype):
        self._note_k_exact(a.magnitude())
    self._land(env, eqn, eqn.outvars[0], out)


@_register("reduce_max")
def _reduce_max(self, env, eqn):
    (a,) = _in(self, env, eqn)
    dominated = frozenset(
        v for v in eqn.invars if not isinstance(v, jax.core.Literal))
    self._land(env, eqn, eqn.outvars[0],
               a.with_(dominates=a.dominates | dominated))


@_register("reduce_min")
def _reduce_min(self, env, eqn):
    (a,) = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0], a.with_(dominates=frozenset()))


@_register("reduce_and", "reduce_or")
def _reduce_bool(self, env, eqn):
    self._land(env, eqn, eqn.outvars[0], Interval.bool01())


@_register("argmax", "argmin")
def _argmax(self, env, eqn):
    n = _axes_size(eqn)
    self._land(env, eqn, eqn.outvars[0],
               Interval(0.0, float(n - 1), int_valued=True))


@_register("cumsum")
def _cumsum(self, env, eqn):
    (a,) = _in(self, env, eqn)
    axis = eqn.params["axis"]
    n = max(eqn.invars[0].aval.shape[axis], 1)
    out = Interval(min(a.lo, a.lo * n), max(a.hi, a.hi * n),
                   int_valued=a.int_valued, reduced=a.reduced or n > 1)
    self._land(env, eqn, eqn.outvars[0], out)


@_register("cummax")
def _cummax(self, env, eqn):
    (a,) = _in(self, env, eqn)
    self._land(env, eqn, eqn.outvars[0], a)


@_register("dot_general")
def _dot_general(self, env, eqn):
    a, b = _in(self, env, eqn)
    (lhs_contract, _rhs_contract), _batch = eqn.params["dimension_numbers"]
    lhs_shape = eqn.invars[0].aval.shape
    k = 1
    for d in lhs_contract:
        k *= lhs_shape[d]
    k = max(k, 1)
    prod = domain.mul(a, b)
    out = Interval(prod.lo * k, prod.hi * k,
                   int_valued=prod.int_valued, reduced=prod.reduced or k > 1)
    if prod.int_valued and k > 1:
        self._note_k_exact(prod.magnitude())
    self._land(env, eqn, eqn.outvars[0], out)


@_register("gather")
def _gather(self, env, eqn):
    operand, indices = _in(self, env, eqn)
    dnums = eqn.params["dimension_numbers"]
    slice_sizes = eqn.params["slice_sizes"]
    op_shape = eqn.invars[0].aval.shape
    self.facts["gathers_checked"] += 1
    ok = True
    mode = eqn.params.get("mode")
    for d in dnums.start_index_map:
        limit = op_shape[d] - slice_sizes[d]
        if indices.lo < 0 or indices.hi > limit:
            ok = False
            self._finding(
                "gather",
                f"gather index envelope [{indices.lo:.6g}, {indices.hi:.6g}] can "
                f"leave [0, {limit}] of operand dim {d} "
                f"(shape {tuple(op_shape)}, slice {tuple(slice_sizes)}, "
                f"mode={mode})",
            )
    if ok:
        self.facts["gathers_proven"] += 1
    self._land(env, eqn, eqn.outvars[0], operand.with_(dominates=frozenset()))


# -- control flow ----------------------------------------------------


@_register("pjit", "closed_call", "custom_jvp_call", "custom_vjp_call",
           "custom_vjp_call_jaxpr", "remat", "checkpoint", "core_call")
def _call(self, env, eqn):
    params = eqn.params
    inner = params.get("jaxpr") or params.get("call_jaxpr") or params.get("fun_jaxpr")
    if inner is None:
        self._finding("unknown",
                      f"call primitive {eqn.primitive.name!r} without inner jaxpr")
        for ov in eqn.outvars:
            env[ov] = Interval.of_dtype(ov.aval.dtype)
        return
    args = _in(self, env, eqn)
    # custom_vjp_call carries extra residual-count invars in some
    # versions; trim/extend defensively to the inner arity.
    n_in = len(inner.jaxpr.invars if hasattr(inner, "jaxpr") else inner.invars)
    if len(args) > n_in:
        args = args[len(args) - n_in:]
    outs = self._descend(inner, args, eqn.primitive.name)
    for ov, o in zip(eqn.outvars, outs[len(outs) - len(eqn.outvars):]):
        env[ov] = o


@_register("cond")
def _cond(self, env, eqn):
    index = self._read(env, eqn.invars[0])
    branches = eqn.params["branches"]
    args = [self._read(env, a) for a in eqn.invars[1:]]
    if index.is_point and 0 <= int(index.lo) < len(branches):
        outs = self._descend(branches[int(index.lo)], args,
                             f"cond[{int(index.lo)}]")
    else:
        # Join over all branches.  Ref writes join into shared cells, so
        # running branches sequentially is the join of their effects.
        all_outs = [self._descend(br, args, f"cond[{i}]")
                    for i, br in enumerate(branches)]
        outs = []
        for vals in zip(*all_outs):
            ivs = [v for v in vals if isinstance(v, Interval)]
            outs.append(domain.join_all(ivs) if ivs else vals[0])
    for ov, o in zip(eqn.outvars, outs):
        env[ov] = o


@_register("scan")
def _scan(self, env, eqn):
    p = eqn.params
    body = p["jaxpr"]
    nc, ncarry, length = p["num_consts"], p["num_carry"], p["length"]
    args = _in(self, env, eqn)
    consts, carry, xs = args[:nc], args[nc:nc + ncarry], args[nc + ncarry:]
    steps = min(length, self.policy.scan_cap)
    ys: list[Interval | None] = None
    for i in range(steps):
        outs = self._descend(body, consts + carry + xs, f"scan[{i}]")
        carry = outs[:ncarry]
        step_ys = outs[ncarry:]
        if ys is None:
            ys = list(step_ys)
        else:
            ys = [y.join(s) if isinstance(y, Interval) and isinstance(s, Interval)
                  else s for y, s in zip(ys, step_ys)]
    if length > steps:
        self._finding("note",
                      f"scan of length {length} capped at {steps}; widening carries")
        carry = [Interval.of_dtype(v.aval.dtype)
                 for v in eqn.outvars[:ncarry]]
        ys = [Interval.of_dtype(v.aval.dtype) for v in eqn.outvars[ncarry:]]
    if ys is None:
        ys = [Interval.of_dtype(v.aval.dtype) for v in eqn.outvars[ncarry:]]
    for ov, o in zip(eqn.outvars, list(carry) + list(ys)):
        env[ov] = o


@_register("while")
def _while(self, env, eqn):
    p = eqn.params
    cn, bn = p["cond_nconsts"], p["body_nconsts"]
    body = p["body_jaxpr"]
    args = _in(self, env, eqn)
    body_consts = args[cn:cn + bn]
    carry = args[cn + bn:]
    for _ in range(self.policy.while_cap):
        outs = self._descend(body, body_consts + carry, "while")
        new_carry = [c.join(o) if isinstance(c, Interval) and isinstance(o, Interval)
                     else o for c, o in zip(carry, outs)]
        if all(isinstance(c, Interval) and isinstance(n_, Interval)
               and c.lo == n_.lo and c.hi == n_.hi
               for c, n_ in zip(carry, new_carry)):
            carry = new_carry
            break
        carry = new_carry
    else:
        self._finding("note", "while loop did not stabilize; widening carry")
        carry = [Interval.of_dtype(v.aval.dtype) for v in eqn.outvars]
    for ov, o in zip(eqn.outvars, carry):
        env[ov] = o


# -- pallas ----------------------------------------------------------


@_register("program_id")
def _program_id(self, env, eqn):
    axis = eqn.params["axis"]
    grid_state = getattr(self, "_grid_state", None)
    if grid_state is not None:
        grid, unrolled_axis, step = grid_state
        if axis == unrolled_axis:
            env[eqn.outvars[0]] = Interval.point(float(step))
            return
        hi = max(0, grid[axis] - 1)
        env[eqn.outvars[0]] = Interval(0.0, float(hi), int_valued=True)
        return
    env[eqn.outvars[0]] = Interval(0.0, _INF, int_valued=True)


@_register("num_programs")
def _num_programs(self, env, eqn):
    axis = eqn.params["axis"]
    grid_state = getattr(self, "_grid_state", None)
    if grid_state is not None:
        env[eqn.outvars[0]] = Interval.point(float(grid_state[0][axis]))
    else:
        env[eqn.outvars[0]] = Interval(1.0, _INF, int_valued=True)


@_register("get")
def _get(self, env, eqn):
    cell = env[eqn.invars[0]]
    out = cell.read() if isinstance(cell, _RefCell) else cell
    self._land(env, eqn, eqn.outvars[0], out)


@_register("swap")
def _swap(self, env, eqn):
    cell = env[eqn.invars[0]]
    val = self._read(env, eqn.invars[1])
    if isinstance(cell, _RefCell):
        old = cell.read()
        cell.write(val)
    else:
        old = cell
    env[eqn.outvars[0]] = old


@_register("addupdate")
def _addupdate(self, env, eqn):
    cell = env[eqn.invars[0]]
    val = self._read(env, eqn.invars[1])
    if isinstance(cell, _RefCell):
        cell.write(domain.add(cell.read(), val))


@_register("pallas_call")
def _pallas_call(self, env, eqn):
    gm = eqn.params["grid_mapping"]
    kernel = eqn.params["jaxpr"]
    grid = tuple(gm.grid)
    n_in, n_out = gm.num_inputs, gm.num_outputs
    args = _in(self, env, eqn)
    invars = kernel.invars
    # kernel invars: [index operands][input refs][output refs][scratch]
    n_scratch = getattr(gm, "num_scratch_operands", 0)
    n_index = max(len(invars) - n_in - n_out - n_scratch, 0)
    bindings: list[Any] = []
    ai = 0
    for _ in range(n_index):
        bindings.append(args[ai] if ai < len(args) else Interval(0.0, _INF, int_valued=True))
        ai += 1
    in_cells = []
    for v in invars[n_index:n_index + n_in]:
        iv = args[ai] if ai < len(args) else Interval.of_dtype(v.aval.dtype)
        ai += 1
        cell = _RefCell(v.aval.dtype, iv)
        in_cells.append(cell)
        bindings.append(cell)
    out_cells = [_RefCell(v.aval.dtype) for v in invars[n_index + n_in:
                                                        n_index + n_in + n_out]]
    bindings.extend(out_cells)
    for v in invars[n_index + n_in + n_out:]:
        bindings.append(_RefCell(v.aval.dtype))

    # Unroll the innermost grid axis (the K/revisit axis in every GEMM
    # kernel here) with a precise program_id so k==0 init branches
    # resolve exactly; other axes stay symbolic.
    steps = grid[-1] if grid else 1
    capped = steps > self.policy.grid_cap
    if capped:
        self._finding("note",
                      f"grid axis of size {steps} capped at {self.policy.grid_cap}")
        steps = self.policy.grid_cap
    prev_grid_state = getattr(self, "_grid_state", None)
    name = eqn.params.get("name", "kernel")
    try:
        for step in range(max(steps, 1)):
            self._grid_state = (grid, len(grid) - 1, step) if grid else None
            self.stack.append(f"pallas_call:{name}[k={step}]")
            try:
                self.run(kernel, [], list(bindings))
            finally:
                self.stack.pop()
    finally:
        self._grid_state = prev_grid_state
    for ov, cell in zip(eqn.outvars, out_cells):
        env[ov] = cell.read()


# prngs / misc: carrier-range results
@_register("random_seed", "random_wrap", "random_bits", "random_unwrap",
           "random_fold_in", "threefry2x32", "random_gamma")
def _random(self, env, eqn):
    for ov in eqn.outvars:
        dtype = getattr(ov.aval, "dtype", None)
        try:
            env[ov] = (Interval.of_dtype(dtype) if dtype is not None
                       else Interval(-_INF, _INF))
        except TypeError:  # opaque dtypes (PRNG key<fry>) have no bounds
            env[ov] = Interval(-_INF, _INF)
