"""VMEM/BlockSpec budget estimation (the third auditor pass).

Two estimators share one budget constant:

* :func:`estimate_pallas_calls` — *measured* from a traced jaxpr: for
  every ``pallas_call`` it sums the BlockSpec tile bytes (doubled for
  the pipeline's double buffering) and adds the peak of live
  intermediate bytes from a liveness walk of the kernel jaxpr.  This
  is what ``launch/analyze.py --report`` emits and what regenerates
  the docs/kernels.md sizing table.

* :func:`tile_footprint` — *closed-form* per (mode, n, t, tiles),
  trace-free and cheap enough to run eagerly inside
  ``engine.config.kernel_tiles`` on every dispatch.  Its per-mode
  transient models are deliberately a superset of the measured
  liveness (asserted in tests), so a tile selection that passes the
  eager gate cannot fail the traced audit on VMEM.

The ~16 MiB/core budget follows the Pallas TPU guidance; the engine
keeps headroom for the compiler's own spills via ``VMEM_BUDGET_BYTES``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import numpy as np

__all__ = [
    "VMEM_BUDGET_BYTES",
    "TileBudgetError",
    "FootprintReport",
    "tile_footprint",
    "validate_tiles",
    "estimate_pallas_calls",
]

# Per-core VMEM on current TPU generations is ~16 MiB; budget the whole
# of it and let the per-mode transient models carry the safety margin.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

# Live intermediate model per mode family, in f32/u32 words (4 bytes):
# cubes are (bm, bk, bn) outer-product intermediates, planes are 2-D
# tiles materialized beside the blocks.  Chosen as a small superset of
# the traced peak liveness (tests pin traced <= modeled).
_SEQMUL_LIVE_CUBES = 8  # a3/b3 broadcasts + recurrence state words
_LUT_LIVE_CUBES = 4  # idx cube + gathered products + sign cube
_PACKED_LIVE_PLANES = 6  # even/odd lanes of both operands + partials
_MXU_LIVE_PLANES = 4  # two dot partials + accumulator temps
_DEFAULT_RANK = 8  # lowrank embedding rank (ApproxConfig default)


class TileBudgetError(ValueError):
    """A (mode, n, t) tile selection exceeds the static VMEM budget."""


@dataclasses.dataclass(frozen=True)
class FootprintReport:
    mode: str
    n: int
    t: int
    tiles: tuple
    block_bytes: int  # one grid step's BlockSpec tiles
    pipeline_bytes: int  # blocks x2 for double buffering
    transient_bytes: int  # modeled live intermediates
    total_bytes: int
    budget_bytes: int = VMEM_BUDGET_BYTES

    @property
    def within_budget(self) -> bool:
        return self.total_bytes <= self.budget_bytes


def _cube(bm: int, bn: int, bk: int) -> int:
    return bm * bk * bn * 4


def tile_footprint(mode: str, n: int, t: int, tiles: tuple) -> FootprintReport:
    """Closed-form VMEM footprint of one grid step of ``mode`` at
    ``tiles = (bm, bn, bk)`` — blocks, double-buffered pipeline copies,
    and the mode's modeled live intermediates."""
    bm, bn, bk = tiles
    operands = 2 * bm * bk + 2 * bk * bn  # mag+sign (or lane pair) tiles
    out = bm * bn
    if mode == "seqmul":
        blocks = (operands + out) * 4
        transient = _SEQMUL_LIVE_CUBES * _cube(bm, bn, bk)
    elif mode == "bitexact":
        lut = (4 ** n) * 4  # (2^n, 2^n) product table pinned whole
        blocks = (operands + out) * 4 + lut
        transient = _LUT_LIVE_CUBES * _cube(bm, bn, bk)
    elif mode == "lowrank":
        r = _DEFAULT_RANK
        blocks = (bm * bk + bk * bn + bm * bk * r + bk * r * bn + out) * 4
        transient = _MXU_LIVE_PLANES * bm * bn * 4
    elif mode == "inject":
        blocks = (bm * bk + bk * bn + out) * 4  # packed u32 operands
        transient = _PACKED_LIVE_PLANES * (bm * bk + bk * bn) * 4 \
            + _MXU_LIVE_PLANES * bm * bn * 4
    else:
        # modes without a fused kernel (exact / fakequant / third-party
        # reference-only registrations) launch no pallas_call
        blocks = 0
        transient = 0
    pipeline = 2 * blocks
    return FootprintReport(
        mode=mode, n=n, t=t, tiles=tuple(tiles),
        block_bytes=blocks, pipeline_bytes=pipeline,
        transient_bytes=transient, total_bytes=pipeline + transient,
    )


def validate_tiles(mode: str, n: int, t: int, tiles: tuple) -> FootprintReport:
    """Eager tile validation for ``engine.config.kernel_tiles``.

    Raises :class:`TileBudgetError` naming the offending (mode, n, t)
    when a tile extent is non-positive, not a power of two, or the
    closed-form footprint exceeds :data:`VMEM_BUDGET_BYTES` — instead
    of failing later inside Pallas lowering.
    """
    bm, bn, bk = tiles
    for name, v in (("bm", bm), ("bn", bn), ("bk", bk)):
        if v <= 0:
            raise TileBudgetError(
                f"kernel_tiles(mode={mode!r}, n={n}, t={t}): tile {name}={v} "
                f"must be positive"
            )
        if v & (v - 1):
            raise TileBudgetError(
                f"kernel_tiles(mode={mode!r}, n={n}, t={t}): tile {name}={v} "
                f"must be a power of two for TPU lane alignment"
            )
    report = tile_footprint(mode, n, t, tiles)
    if not report.within_budget:
        raise TileBudgetError(
            f"kernel_tiles(mode={mode!r}, n={n}, t={t}): tiles "
            f"(bm={bm}, bn={bn}, bk={bk}) need {report.total_bytes / 2**20:.2f} "
            f"MiB of VMEM ({report.pipeline_bytes / 2**20:.2f} blocks + "
            f"{report.transient_bytes / 2**20:.2f} transient), over the "
            f"{report.budget_bytes / 2**20:.0f} MiB budget"
        )
    return report


# ------------------------------------------------------------- traced pass


def _aval_bytes(aval: Any) -> int:
    shape = getattr(aval, "shape", ())
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize if shape \
        else np.dtype(dtype).itemsize


def _is_ref(var: Any) -> bool:
    return hasattr(var.aval, "inner_aval")


def _inner_jaxprs(eqn: Any) -> list[Any]:
    out = []
    for v in eqn.params.values():
        if isinstance(v, jax.core.ClosedJaxpr):
            out.append(v.jaxpr)
        elif isinstance(v, jax.core.Jaxpr):
            out.append(v)
        elif isinstance(v, (tuple, list)):
            for e in v:
                if isinstance(e, jax.core.ClosedJaxpr):
                    out.append(e.jaxpr)
                elif isinstance(e, jax.core.Jaxpr):
                    out.append(e)
    return out


def peak_live_bytes(jaxpr: Any, *, count_inputs: bool = True) -> int:
    """Peak of live non-ref intermediate bytes over a linear walk.

    Sub-jaxprs (scan/cond bodies, pjit calls) contribute their own peak
    on top of the live set at their call point — with their *inputs*
    excluded, since a call operand is the caller's buffer and is already
    counted in the caller's live set (it stays live through the call
    equation).  Refs are excluded — their bytes are the BlockSpec
    tiles, counted by the caller.
    """
    last_use: dict[Any, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for a in eqn.invars:
            if isinstance(a, jax.core.Var):
                last_use[a] = i
    for v in jaxpr.outvars:
        if isinstance(v, jax.core.Var):
            last_use[v] = len(jaxpr.eqns)

    live: dict[Any, int] = {}
    if count_inputs:
        for v in list(jaxpr.invars) + list(jaxpr.constvars):
            if not _is_ref(v) and v in last_use:
                live[v] = _aval_bytes(v.aval)
    peak = sum(live.values())
    for i, eqn in enumerate(jaxpr.eqns):
        inner_peak = 0
        for inner in _inner_jaxprs(eqn):
            inner_peak = max(inner_peak,
                             peak_live_bytes(inner, count_inputs=False))
        for v in eqn.outvars:
            if not _is_ref(v):
                live[v] = _aval_bytes(v.aval)
        peak = max(peak, sum(live.values()) + inner_peak)
        for a in list(eqn.invars) + list(eqn.outvars):
            if isinstance(a, jax.core.Var) and last_use.get(a, math.inf) <= i:
                live.pop(a, None)
    return peak


def _walk_pallas(jaxpr: Any, found: list[Any]) -> None:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            found.append(eqn)
        for inner in _inner_jaxprs(eqn):
            _walk_pallas(inner, found)


def estimate_pallas_calls(closed: jax.core.ClosedJaxpr) -> list[dict]:
    """Measured VMEM estimate for every ``pallas_call`` in a trace."""
    eqns: list[Any] = []
    _walk_pallas(closed.jaxpr, eqns)
    reports = []
    for eqn in eqns:
        gm = eqn.params["grid_mapping"]
        kernel = eqn.params["jaxpr"]
        block_bytes = 0
        for bm_ in gm.block_mappings:
            shape = tuple(int(d) for d in bm_.block_shape)
            dtype = bm_.array_shape_dtype.dtype
            block_bytes += int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
        live = peak_live_bytes(kernel)
        total = 2 * block_bytes + live
        reports.append({
            "name": eqn.params.get("name", "kernel"),
            "grid": tuple(int(g) for g in gm.grid),
            "block_bytes": int(block_bytes),
            "pipeline_bytes": int(2 * block_bytes),
            "live_bytes": int(live),
            "total_bytes": int(total),
            "budget_bytes": VMEM_BUDGET_BYTES,
            "within_budget": bool(total <= VMEM_BUDGET_BYTES),
        })
    return reports
