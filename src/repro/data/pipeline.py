"""Deterministic synthetic data pipeline with sharded host loading.

Real multi-pod deployments feed each host only its slice of the global
batch; the loader here follows that contract: ``host_batch_slice`` returns
the (process_index, process_count)-dependent row range, and every batch is
generated *counter-based* (seed = hash(seed, step)) so that a restart at
step k reproduces exactly the batch the failed run would have seen — a
requirement for deterministic recovery (runtime/fault.py).

The synthetic distribution is a Zipf-like unigram mix with a shifted-copy
structure (labels are next-token), giving a learnable non-uniform stream
whose loss visibly decreases within a few hundred steps (examples/).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "host_batch_slice"]


def host_batch_slice(global_batch: int, process_index: int, process_count: int) -> slice:
    if global_batch % process_count != 0:
        raise ValueError(f"global_batch {global_batch} not divisible by hosts {process_count}")
    per = global_batch // process_count
    return slice(process_index * per, (process_index + 1) * per)


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2  # unigram skew
    copy_period: int = 64  # structure: token[t] depends on token[t - period]


class SyntheticLM:
    """Counter-based synthetic LM stream.

    ``batch(step)`` is a pure function of (config, step): restartable and
    identical across hosts (each host then slices its rows).
    """

    def __init__(self, cfg: DataConfig, process_index: int = 0, process_count: int = 1):
        self.cfg = cfg
        self._slice = host_batch_slice(cfg.global_batch, process_index, process_count)
        # fixed Zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(key=cfg.seed, counter=step))
        tok = rng.choice(
            cfg.vocab_size, size=(cfg.global_batch, cfg.seq_len + 1), p=self._p
        ).astype(np.int32)
        # inject copy structure: with p=0.5 repeat the token copy_period back
        if cfg.copy_period and cfg.seq_len + 1 > cfg.copy_period:
            mask = rng.random((cfg.global_batch, cfg.seq_len + 1)) < 0.5
            mask[:, : cfg.copy_period] = False
            shifted = np.roll(tok, cfg.copy_period, axis=1)
            tok = np.where(mask, shifted, tok)
        tok = tok[self._slice]
        return {
            "tokens": tok[:, :-1],
            "labels": tok[:, 1:],
        }

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
