"""Sharded, async, preemption-safe checkpointing with elastic restore.

Format: one ``step_<k>.npz`` per save holding the flattened leaves of the
train state (+ a tiny JSON manifest marking the latest complete step).
Writes go to a temp file first and are renamed atomically, so a
preemption mid-write never corrupts the latest checkpoint.  Saves run on
a background thread (``wait()`` joins); the training loop is never
blocked on disk.

Elastic restore: ``restore(target_like=...)`` unflattens into *any*
target structure with matching leaves and ``jax.device_put``s each leaf
to the target's sharding — so a run checkpointed on mesh A resumes on
mesh B (different device count / axis sizes) as long as the logical
shapes are unchanged.  This is the restart path for node failures and
elastic rescaling.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- paths
    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}.npz")

    def _manifest(self) -> str:
        return os.path.join(self.directory, "manifest.json")

    def latest_step(self) -> Optional[int]:
        try:
            with open(self._manifest()) as f:
                return json.load(f)["step"]
        except (FileNotFoundError, json.JSONDecodeError, KeyError):
            return None

    # -------------------------------------------------------------- save
    def save(self, step: int, state: Any, *, blocking: bool = False) -> None:
        leaves = jax.tree_util.tree_leaves(state)
        host = [np.asarray(x) for x in leaves]  # device->host copy, sync

        def write():
            # NB: np.savez appends ".npz" unless the name already ends in it
            tmp = self._path(step)[: -len(".npz")] + ".tmp.npz"
            np.savez(tmp, **{f"leaf_{i}": a for i, a in enumerate(host)})
            os.replace(tmp, self._path(step))
            mtmp = self._manifest() + ".tmp"
            with open(mtmp, "w") as f:
                json.dump({"step": step, "n_leaves": len(host)}, f)
            os.replace(mtmp, self._manifest())
            self._prune()

        self.wait()
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self) -> None:
        ckpts = sorted(
            f for f in os.listdir(self.directory)
            if f.startswith("step_") and f.endswith(".npz") and ".tmp" not in f
        )
        for f in ckpts[: -self.keep] if self.keep else []:
            try:
                os.remove(os.path.join(self.directory, f))
            except OSError:
                pass

    # ------------------------------------------------------------ restore
    def restore(self, target_like: Any, step: Optional[int] = None) -> tuple[Any, int]:
        """Load into the structure (and shardings) of ``target_like``."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        with np.load(self._path(step)) as z:
            host = [z[f"leaf_{i}"] for i in range(len(z.files))]
        t_leaves, treedef = jax.tree_util.tree_flatten(target_like)
        if len(t_leaves) != len(host):
            raise ValueError(
                f"checkpoint has {len(host)} leaves, target {len(t_leaves)} — "
                "structure changed since save"
            )
        out = []
        for tgt, arr in zip(t_leaves, host):
            if tuple(tgt.shape) != tuple(arr.shape):
                raise ValueError(f"shape mismatch {tgt.shape} vs {arr.shape}")
            arr = arr.astype(tgt.dtype)
            sharding = getattr(tgt, "sharding", None)
            out.append(jax.device_put(arr, sharding) if sharding is not None else arr)
        return jax.tree_util.tree_unflatten(treedef, out), step
