"""Pallas TPU kernel: int-packed GEMM — two quantized lanes per uint32.

The cheap quality tiers must move *fewer bytes*, not just spend fewer
abstract gate delays (the energy/latency framing of the approximate-
multiplier literature).  This kernel is the ``draft``-tier fast path:
both operands are absmax-quantized to signed n-bit integers (n <= 15,
i.e. int16 lanes), packed two-consecutive-K-values per uint32 on the
host side, and streamed through the (M/BM, N/BN, K'/BK') reduction grid
at **half the HBM bytes of the f32 operands** (K' = K/2 packed words).

Inside the kernel each packed tile is bitcast to int32 and split into
its even/odd int16 lanes with arithmetic shifts; the contraction is two
MXU dots (even-lane plane + odd-lane plane) into the VMEM-resident f32
accumulator:

    acc += a_even @ b_even + a_odd @ b_odd      == qa @ qb  (exact)

Quantized values are integers |q| < 2^n, so the f32 accumulation is
exact for n <= 11 over the benchmarked K range — the packed path
bit-matches the unpacked quantized GEMM, asserted in
``tests/test_fused_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.engine.policy import resolve_interpret

__all__ = ["pack_i16_pairs", "packed_matmul_pallas", "DEFAULT_BM", "DEFAULT_BN", "DEFAULT_BK"]

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 64  # packed words: 64 u32 = 128 int16 K-lanes per tile


def pack_i16_pairs(q: jax.Array, *, axis: int) -> jax.Array:
    """Pack consecutive pairs along ``axis`` of a signed-int array into
    uint32 words (low half = even index, high half = odd index).  Pads the
    axis to even length with zeros; values must fit int16."""
    q = jnp.asarray(q, jnp.int32)
    if q.shape[axis] % 2:
        pad = [(0, 0)] * q.ndim
        pad[axis] = (0, 1)
        q = jnp.pad(q, pad)
    even = jax.lax.slice_in_dim(q, 0, q.shape[axis], stride=2, axis=axis)
    odd = jax.lax.slice_in_dim(q, 1, q.shape[axis], stride=2, axis=axis)
    word = (even & jnp.int32(0xFFFF)) | (odd << 16)
    return jax.lax.bitcast_convert_type(word, jnp.uint32)


def _unpack(tile: jax.Array) -> tuple[jax.Array, jax.Array]:
    """uint32 tile -> (even, odd) f32 lanes via sign-extending shifts."""
    w = jax.lax.bitcast_convert_type(tile, jnp.int32)
    even = jax.lax.shift_right_arithmetic(jax.lax.shift_left(w, 16), 16)
    odd = jax.lax.shift_right_arithmetic(w, 16)
    return even.astype(jnp.float32), odd.astype(jnp.float32)


def _kernel(pa_ref, pb_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_even, a_odd = _unpack(pa_ref[...])  # (BM, BK') each
    b_even, b_odd = _unpack(pb_ref[...])  # (BK', BN) each
    acc = jnp.dot(a_even, b_even, preferred_element_type=jnp.float32)
    acc += jnp.dot(a_odd, b_odd, preferred_element_type=jnp.float32)
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _packed_matmul_jit(
    pa: jax.Array,  # (M, K') uint32 — packed along K
    pb: jax.Array,  # (K', N) uint32
    *,
    bm: int,
    bn: int,
    bk: int,
    interpret: bool,
) -> jax.Array:
    m_dim, kp_dim = pa.shape
    kp2, n_dim = pb.shape
    assert kp_dim == kp2, (pa.shape, pb.shape)

    def pad2(x, r, c):
        return jnp.pad(jnp.asarray(x, jnp.uint32), ((0, -x.shape[0] % r), (0, -x.shape[1] % c)))

    ap = pad2(pa, bm, bk)
    bp = pad2(pb, bk, bn)
    mp, kp, np_ = ap.shape[0], ap.shape[1], bp.shape[1]

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(ap, bp)
    return out[:m_dim, :n_dim]


def packed_matmul_pallas(
    pa: jax.Array,
    pb: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool | None = None,
) -> jax.Array:
    """Packed (M, K/2) x (K/2, N) -> (M, N) f32 integer GEMM.

    Operands come from :func:`pack_i16_pairs` along the contraction axis
    (axis=1 for the left operand, axis=0 for the right).  ``interpret=None``
    resolves through the engine's shared backend policy.
    """
    return _packed_matmul_jit(
        pa, pb, bm=bm, bn=bn, bk=bk, interpret=resolve_interpret(interpret)
    )


def audit_trace(*, n: int = 15, t: int = 0, bm: int = DEFAULT_BM,
                bn: int = DEFAULT_BN, bk: int = DEFAULT_BK):
    """Static-audit contract for the packed GEMM (no execution).

    Operands are arbitrary uint32 words (any int16 lane pattern): the
    audit proves the sign-extending lane extraction and the two-plane
    contraction never overflow their carriers.  Lane *value* bounds are
    erased by the bit-packing, so f32-exactness of the products is a
    runtime parity property (tests), not a static one — the trace runs
    with ``exact_products=False``.
    """
    del n, t
    from repro.analysis.spec import TraceSpec, sds

    fn = functools.partial(_packed_matmul_jit, bm=bm, bn=bn, bk=bk,
                           interpret=True)
    return TraceSpec(
        name="kernel:packed_matmul",
        fn=fn,
        args=[sds((bm, 2 * bk), jnp.uint32), sds((2 * bk, bn), jnp.uint32)],
        exact_products=False,
    )
