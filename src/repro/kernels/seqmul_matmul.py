"""Pallas TPU kernel: fused approximate GEMM with the splitting point ``t``
*inside* the tile loop.

This is the paper's segmented-carry sequential multiplier deployed as a
blocked GEMM instead of an elementwise post-pass.  Historically a
"seqmul" matmul meant: flatten the (M, K, N) outer-product pairs, run the
elementwise kernel (`kernels.seqmul_kernel`) over O(M·K·N) words in HBM,
then reduce — the recurrence was an *outer loop around* generic kernels
and the intermediate product tensor round-tripped through HBM.

Here the grid is the classic (M/BM, N/BN, K/BK) reduction layout with the
K axis innermost and the f32 accumulator tile resident in VMEM (init at
k==0, accumulate after).  Each grid step broadcasts its (BM, BK) × (BK, BN)
magnitude tiles to a (BM, BK, BN) cube *in VMEM*, runs the n-cycle
split-word recurrence from `repro.engine.recurrence` — the same single
body the jnp reference and the elementwise kernel use, so bit-exactness
is structural — assembles product values in f32, applies the
sign-magnitude rank-1 sign product, and reduces over the tile's K extent
into the accumulator.  Nothing of O(M·K·N) ever exists outside VMEM.

Accumulations are exact: products are integers < 2^{2n} and partial sums
stay integer-valued in f32 for n <= 12 and K within the tested range
(|sum| < 2^24), so the tile reduction order cannot perturb the result —
asserted against the reference oracle in ``tests/test_fused_kernels.py``.

VMEM budget: the recurrence keeps ~6 live uint32 cubes of shape
(BM, BK, BN); the default 32³ tiles put that at ~768 KiB, well under the
~16 MiB/core budget (see docs/kernels.md for the sizing table).  Tile
sizes are resolved per call by ``engine.config.kernel_tiles`` so quality
tiers can trade tile footprint against grid overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.engine.policy import resolve_interpret
from repro.engine.recurrence import seqmul_recurrence, validate_nt

__all__ = ["seqmul_matmul_pallas", "DEFAULT_BM", "DEFAULT_BN", "DEFAULT_BK"]

# 32^3 u32 cube = 128 KiB per live recurrence word (~6 live) — comfortably
# inside VMEM while keeping the grid coarse enough to amortize dispatch.
DEFAULT_BM = 32
DEFAULT_BN = 32
DEFAULT_BK = 32


def _kernel(ma_ref, sa_ref, mb_ref, sb_ref, o_ref, *, n, t, approx, fix_to_1):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ma = ma_ref[...]  # (BM, BK) uint32 magnitudes
    mb = mb_ref[...]  # (BK, BN)
    bm, bk = ma.shape
    bn = mb.shape[1]
    # The splitting point t lives HERE: the n-cycle segmented-carry
    # recurrence runs on the (BM, BK, BN) outer-product cube in VMEM.
    a3 = jnp.broadcast_to(ma[:, :, None], (bm, bk, bn))
    b3 = jnp.broadcast_to(mb[None, :, :], (bm, bk, bn))
    lo, s_lsp, s_msp, _ = seqmul_recurrence(
        a3, b3, n=n, t=t, approx=approx, fix_to_1=fix_to_1
    )
    # assemble the 2n-bit product value in f32 (exact for n <= 12)
    prod = lo.astype(jnp.float32) + jnp.float32(1 << (n - 1)) * (
        s_lsp.astype(jnp.float32) + jnp.float32(1 << t) * s_msp.astype(jnp.float32)
    )
    signs = sa_ref[...][:, :, None] * sb_ref[...][None, :, :]
    o_ref[...] += (prod * signs).sum(axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("n", "t", "approx", "fix_to_1", "bm", "bn", "bk", "interpret"),
)
def _seqmul_matmul_jit(
    mag_a: jax.Array,
    sign_a: jax.Array,
    mag_b: jax.Array,
    sign_b: jax.Array,
    *,
    n: int,
    t: int,
    approx: bool,
    fix_to_1: bool,
    bm: int,
    bn: int,
    bk: int,
    interpret: bool,
) -> jax.Array:
    m_dim, k_dim = mag_a.shape
    k2, n_dim = mag_b.shape
    assert k_dim == k2, (mag_a.shape, mag_b.shape)

    def pad2(x, r, c, dt):
        x = jnp.asarray(x, dt)
        return jnp.pad(x, ((0, -x.shape[0] % r), (0, -x.shape[1] % c)))

    # zero-magnitude / zero-sign padding contributes exactly 0 to every
    # accumulator cell (0·0 never produces an LSP carry, so fix-to-1
    # cannot fire on pad lanes)
    ma = pad2(mag_a, bm, bk, jnp.uint32)
    sa = pad2(sign_a, bm, bk, jnp.float32)
    mb = pad2(mag_b, bk, bn, jnp.uint32)
    sb = pad2(sign_b, bk, bn, jnp.float32)
    mp, kp, np_ = ma.shape[0], ma.shape[1], mb.shape[1]

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, n=n, t=t, approx=approx, fix_to_1=fix_to_1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(ma, sa, mb, sb)
    return out[:m_dim, :n_dim]


def seqmul_matmul_pallas(
    mag_a: jax.Array,
    sign_a: jax.Array,
    mag_b: jax.Array,
    sign_b: jax.Array,
    *,
    n: int,
    t: int,
    approx: bool = True,
    fix_to_1: bool = True,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool | None = None,
) -> jax.Array:
    """(M, K) x (K, N) -> (M, N) f32 approximate GEMM, recurrence in-tile.

    mag_*: uint32 magnitudes in [0, 2^n); sign_*: f32/int8 in {-1, 0, 1}.
    ``interpret=None`` resolves through the engine's shared backend policy.
    """
    validate_nt(n, t)
    if n > 12:
        raise ValueError(
            f"seqmul_matmul_pallas accumulates assembled products in f32, "
            f"exact only for n <= 12 (got n={n}); use the elementwise "
            f"two-word path (kernels.seqmul_kernel.seqmul_pallas_words) "
            f"for wider operands"
        )
    return _seqmul_matmul_jit(
        mag_a, sign_a, mag_b, sign_b,
        n=n, t=t, approx=approx, fix_to_1=fix_to_1,
        bm=bm, bn=bn, bk=bk, interpret=resolve_interpret(interpret),
    )


def audit_trace(*, n: int, t: int, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                bk: int = DEFAULT_BK):
    """Static-audit contract for the fused seqmul GEMM (no execution).

    Traces ``_seqmul_matmul_jit`` directly — *bypassing* the public
    ``n <= 12`` guard — under the documented input contract (magnitudes
    in ``[0, 2^n - 1]``, signs in {-1, 0, 1}), so the f32-exactness
    bound is rediscovered by ``repro.analysis`` as a derived fact
    rather than assumed from this module's docstring.
    """
    from repro.analysis.spec import TraceSpec, ValueRange, sds

    fn = functools.partial(
        _seqmul_matmul_jit, n=n, t=t, approx=True, fix_to_1=True,
        bm=bm, bn=bn, bk=bk, interpret=True,
    )
    q, s = ValueRange.quantized(n), ValueRange.sign()
    m_dim, k_dim, n_dim = bm, 2 * bk, bn
    return TraceSpec(
        name=f"kernel:seqmul_matmul[n={n},t={t}]",
        fn=fn,
        args=[sds((m_dim, k_dim), jnp.uint32), sds((m_dim, k_dim), jnp.float32),
              sds((k_dim, n_dim), jnp.uint32), sds((k_dim, n_dim), jnp.float32)],
        ranges=[q, s, q, s],
        exact_products=True,
    )
