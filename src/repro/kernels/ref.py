"""Pure-jnp oracles for every Pallas kernel in this package.

Each function computes exactly what the corresponding kernel must produce
(same dtypes, same padding-free semantics); the kernel tests sweep shapes,
bit-widths and splitting points and assert allclose/bit-equality.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import seqmul as _sm
from repro.engine.modes import bitexact_gemm_int as approx_matmul_int


def seqmul_ref(
    a: jax.Array, b: jax.Array, *, n: int, t: int, approx: bool = True, fix_to_1: bool = True
) -> jax.Array:
    """Packed-u32 elementwise (approximate) sequential product, 2n <= 31."""
    w = _sm.seq_mul_words(
        jnp.asarray(a, jnp.uint32), jnp.asarray(b, jnp.uint32), n=n, t=t, approx=approx, fix_to_1=fix_to_1
    )
    s = w.s_lsp + (w.s_msp << t)
    return w.lo + (s << (n - 1))


def lut_matmul_ref(
    mag_a: jax.Array,
    sign_a: jax.Array,
    mag_b: jax.Array,
    sign_b: jax.Array,
    *,
    n: int,
    t: int,
    fix_to_1: bool = True,
) -> jax.Array:
    """Bit-exact signed approximate GEMM oracle (gather + reduce in jnp)."""
    return approx_matmul_int(
        jnp.asarray(mag_a, jnp.uint32),
        jnp.asarray(sign_a),
        jnp.asarray(mag_b, jnp.uint32),
        jnp.asarray(sign_b),
        n=n,
        t=t,
        fix_to_1=fix_to_1,
    )


def lowrank_matmul_ref(a, b, ue, ve) -> jax.Array:
    """Exact GEMM + low-rank correction oracle."""
    exact = jnp.asarray(a, jnp.float32) @ jnp.asarray(b, jnp.float32)
    corr = jnp.einsum("ikr,kjr->ij", jnp.asarray(ue, jnp.float32), jnp.asarray(ve, jnp.float32))
    return exact + corr
