"""Pallas TPU kernel: approximate GEMM via a VMEM-resident product LUT.

TPU adaptation of the paper's LUT-fabric deployment: the full
(2^n, 2^n) approximate-product table (256 KiB at n=8, int32) is pinned in
VMEM once per core; each (BM, BK)x(BK, BN) tile contraction gathers its
scalar products from the table instead of re-simulating the bit-serial
datapath.  Signs ride separately (sign-magnitude wrapper of the unsigned
multiplier), applied as an f32 rank-1 product before the K-reduction.

Grid is (M/BM, N/BN, K/BK) with the K axis innermost and the output block
revisited across K (init at k==0, accumulate after) — the classic Pallas
reduction pattern, keeping one f32 accumulator tile live in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.engine.policy import resolve_interpret

DEFAULT_BM = 64
DEFAULT_BN = 64
DEFAULT_BK = 64


def _kernel(lut_ref, ma_ref, sa_ref, mb_ref, sb_ref, o_ref, *, n: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Clamp magnitudes into the table's [0, 2^n) domain before forming the
    # gather index: an out-of-range quantized magnitude (buggy upstream
    # calibration, adversarial operands) must saturate to the table edge
    # instead of gathering from another row's products — or, in native
    # lowering, from out-of-bounds VMEM.
    qmax = jnp.int32((1 << n) - 1)
    ma = jnp.minimum(ma_ref[...].astype(jnp.int32), qmax)  # (BM, BK)
    mb = jnp.minimum(mb_ref[...].astype(jnp.int32), qmax)  # (BK, BN)
    idx = ma[:, :, None] * (1 << n) + mb[None, :, :]  # (BM, BK, BN)
    prod = jnp.take(lut_ref[...].reshape(-1), idx, axis=0).astype(jnp.float32)
    signs = sa_ref[...][:, :, None] * sb_ref[...][None, :, :]
    o_ref[...] += (prod * signs).sum(axis=1)


@functools.partial(
    jax.jit, static_argnames=("n", "bm", "bn", "bk", "interpret")
)
def _lut_matmul_jit(
    lut: jax.Array,
    mag_a: jax.Array,
    sign_a: jax.Array,
    mag_b: jax.Array,
    sign_b: jax.Array,
    *,
    n: int,
    bm: int,
    bn: int,
    bk: int,
    interpret: bool,
) -> jax.Array:
    m_dim, k_dim = mag_a.shape
    k2, n_dim = mag_b.shape
    assert k_dim == k2, (mag_a.shape, mag_b.shape)
    lut = lut.reshape(1 << n, 1 << n)

    def pad2(x, r, c, dt):
        x = jnp.asarray(x, dt)
        return jnp.pad(x, ((0, -x.shape[0] % r), (0, -x.shape[1] % c)))

    ma = pad2(mag_a, bm, bk, jnp.uint32)
    sa = pad2(sign_a, bm, bk, jnp.float32)
    mb = pad2(mag_b, bk, bn, jnp.uint32)
    sb = pad2(sign_b, bk, bn, jnp.float32)
    mp, kp, np_ = ma.shape[0], ma.shape[1], mb.shape[1]

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1 << n, 1 << n), lambda i, j, k: (0, 0)),  # LUT: whole
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(lut, ma, sa, mb, sb)
    return out[:m_dim, :n_dim]


def lut_matmul_pallas(
    lut: jax.Array,
    mag_a: jax.Array,
    sign_a: jax.Array,
    mag_b: jax.Array,
    sign_b: jax.Array,
    *,
    n: int = 8,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool | None = None,
) -> jax.Array:
    """(M, K) x (K, N) -> (M, N) f32 approximate GEMM.

    lut: (2^n * 2^n,) or (2^n, 2^n) int32 product table.
    mag_*: uint32 magnitudes in [0, 2^n); sign_*: f32/int8 in {-1, 0, 1}.
    ``interpret=None`` resolves through the engine's shared backend policy.
    """
    return _lut_matmul_jit(
        lut, mag_a, sign_a, mag_b, sign_b,
        n=n, bm=bm, bn=bn, bk=bk, interpret=resolve_interpret(interpret),
    )


def audit_trace(*, n: int, t: int = 0, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                bk: int = DEFAULT_BK, mag_slack_bits: int = 2):
    """Static-audit contract for the LUT GEMM (no execution).

    The magnitude contract is deliberately *adversarial*: inputs range
    over ``[0, 2^{n + mag_slack_bits} - 1]`` — a miscalibrated upstream
    quantizer — so what ``repro.analysis`` proves is that the in-kernel
    edge clamp keeps every gather inside the (2^n, 2^n) table even for
    out-of-contract magnitudes.  (``t`` only shapes the table contents,
    not the dataflow; accepted for interface uniformity.)
    """
    del t
    from repro.analysis.spec import TraceSpec, ValueRange, sds

    fn = functools.partial(_lut_matmul_jit, n=n, bm=bm, bn=bn, bk=bk,
                           interpret=True)
    mag = ValueRange(0.0, float((1 << (n + mag_slack_bits)) - 1), int_valued=True)
    sgn = ValueRange.sign()
    # table values are approximate products, bounded by the exact max
    lut_vals = ValueRange(0.0, float(((1 << n) - 1) ** 2), int_valued=True)
    m_dim, k_dim, n_dim = bm, 2 * bk, bn
    return TraceSpec(
        name=f"kernel:lut_matmul[n={n}]",
        fn=fn,
        args=[sds(((1 << n) * (1 << n),), jnp.int32),
              sds((m_dim, k_dim), jnp.uint32), sds((m_dim, k_dim), jnp.float32),
              sds((k_dim, n_dim), jnp.uint32), sds((k_dim, n_dim), jnp.float32)],
        ranges=[lut_vals, mag, sgn, mag, sgn],
        exact_products=True,
    )
