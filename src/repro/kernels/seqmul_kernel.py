"""Pallas TPU kernel: tiled elementwise approximate sequential multiply.

The bit-serial datapath of the paper maps onto the TPU VPU as n iterations
of uint32 word ops over (block_rows, 128) VMEM tiles — the lane dimension
is the hardware's native 128, the sublane blocking is chosen so all live
tiles (two inputs, one output, loop state) stay well under VMEM.

The kernel body imports the *same* split-word recurrence as the reference
(`repro.engine.recurrence`, also used by `core.seqmul`); only the memory
orchestration (BlockSpec tiling, grid) is kernel-specific, so
bit-exactness against the oracle is structural and asserted in tests over
shape/dtype/config sweeps.

``interpret=None`` (the default) resolves through the engine's shared
backend policy: native lowering on TPU, interpret mode elsewhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.engine.policy import resolve_interpret
from repro.engine.recurrence import pack_u32, seqmul_recurrence, validate_nt

LANES = 128
DEFAULT_BLOCK_ROWS = 64  # (64, 128) u32 tiles = 32 KiB per operand buffer


def _kernel(a_ref, b_ref, o_ref, *, n, t, approx, fix_to_1):
    lo, s_lsp, s_msp, _ = seqmul_recurrence(
        a_ref[...], b_ref[...], n=n, t=t, approx=approx, fix_to_1=fix_to_1
    )
    # packed 2n-bit product (valid for 2n <= 31)
    o_ref[...] = pack_u32(lo, s_lsp, s_msp, n=n, t=t)


def _split_words(lo, s_lsp, s_msp, *, n, t):
    """(low, high) uint32 words of the 2n-bit product, overflow-free for
    any n <= 16: ``low`` holds product bits [0, n), ``high`` bits [n, 2n].
    The accumulator word s = s_lsp + (s_msp << t) is at most n+2 bits, so
    ``s >> 1`` never overflows where ``s << (n-1)`` (the single-word
    packing) would."""
    s = s_lsp + (s_msp << t)
    one = jnp.uint32(1)
    low = lo | ((s & one) << (n - 1))
    high = s >> one
    return low, high


def _words_kernel(a_ref, b_ref, lo_ref, hi_ref, *, n, t, approx, fix_to_1):
    lo, s_lsp, s_msp, _ = seqmul_recurrence(
        a_ref[...], b_ref[...], n=n, t=t, approx=approx, fix_to_1=fix_to_1
    )
    low, high = _split_words(lo, s_lsp, s_msp, n=n, t=t)
    lo_ref[...] = low
    hi_ref[...] = high


@functools.partial(
    jax.jit,
    static_argnames=("n", "t", "approx", "fix_to_1", "block_rows", "interpret"),
)
def _seqmul_pallas_jit(
    a: jax.Array,
    b: jax.Array,
    *,
    n: int,
    t: int,
    approx: bool,
    fix_to_1: bool,
    block_rows: int,
    interpret: bool,
) -> jax.Array:
    if 2 * n > 31:
        raise ValueError("packed kernel supports 2n <= 31 bits")
    shape = a.shape
    flat = a.size
    rows = -(-max(flat, 1) // LANES)
    rows_pad = -(-rows // block_rows) * block_rows
    pad = rows_pad * LANES - flat

    def prep(x):
        x = jnp.asarray(x, jnp.uint32).reshape(-1)
        return jnp.pad(x, (0, pad)).reshape(rows_pad, LANES)

    a2, b2 = prep(a), prep(b)
    grid = (rows_pad // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, n=n, t=t, approx=approx, fix_to_1=fix_to_1),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANES), jnp.uint32),
        interpret=interpret,
    )(a2, b2)
    return out.reshape(-1)[:flat].reshape(shape)


@functools.partial(
    jax.jit,
    static_argnames=("n", "t", "approx", "fix_to_1", "block_rows", "interpret"),
)
def _seqmul_words_jit(
    a: jax.Array,
    b: jax.Array,
    *,
    n: int,
    t: int,
    approx: bool,
    fix_to_1: bool,
    block_rows: int,
    interpret: bool,
) -> tuple[jax.Array, jax.Array]:
    shape = a.shape
    flat = a.size
    rows = -(-max(flat, 1) // LANES)
    rows_pad = -(-rows // block_rows) * block_rows
    pad = rows_pad * LANES - flat

    def prep(x):
        x = jnp.asarray(x, jnp.uint32).reshape(-1)
        return jnp.pad(x, (0, pad)).reshape(rows_pad, LANES)

    a2, b2 = prep(a), prep(b)
    grid = (rows_pad // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    low, high = pl.pallas_call(
        functools.partial(_words_kernel, n=n, t=t, approx=approx, fix_to_1=fix_to_1),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((rows_pad, LANES), jnp.uint32),
            jax.ShapeDtypeStruct((rows_pad, LANES), jnp.uint32),
        ],
        interpret=interpret,
    )(a2, b2)

    def post(x):
        return x.reshape(-1)[:flat].reshape(shape)

    return post(low), post(high)


def seqmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    n: int,
    t: int,
    approx: bool = True,
    fix_to_1: bool = True,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> jax.Array:
    """Elementwise approximate product of uint32 arrays (any shape).

    Flattens, pads to a (rows, 128) layout, launches a 1-D grid of
    (block_rows, 128) tiles, then restores the original shape.

    Validation is eager (before any tracing): (n, t) must be a valid
    split and the packed single-word output needs 2n <= 31 — wider
    configurations (the paper's n=16) use :func:`seqmul_pallas_words`.
    """
    validate_nt(n, t)
    if 2 * n > 31:
        raise ValueError(
            f"packed kernel supports 2n <= 31 bits (got n={n}, 2n={2 * n}); "
            f"use seqmul_pallas_words for the two-word (low, high) output"
        )
    return _seqmul_pallas_jit(
        a, b, n=n, t=t, approx=approx, fix_to_1=fix_to_1,
        block_rows=block_rows, interpret=resolve_interpret(interpret),
    )


def seqmul_pallas_words(
    a: jax.Array,
    b: jax.Array,
    *,
    n: int,
    t: int,
    approx: bool = True,
    fix_to_1: bool = True,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Widened elementwise product: returns ``(low, high)`` uint32 words.

    ``low`` holds product bits [0, n), ``high`` bits [n, 2n] — the full
    2n-bit product is ``low + (high << n)`` (assembled on host in uint64
    for n > 15).  This is the path that serves the paper's n=16
    configuration, where the single-word packing (2n <= 31) cannot.
    """
    validate_nt(n, t)
    if n > 16:
        raise ValueError(
            f"two-word output holds bits [0, 2n] across two uint32 words "
            f"with the recurrence in uint32 lanes, which needs n <= 16 "
            f"(got n={n})"
        )
    return _seqmul_words_jit(
        a, b, n=n, t=t, approx=approx, fix_to_1=fix_to_1,
        block_rows=block_rows, interpret=resolve_interpret(interpret),
    )


def audit_trace_packed(*, n: int, t: int, block_rows: int = 8):
    """Static-audit contract for the packed single-u32 elementwise kernel.

    Builds a ``pallas_call`` around ``_kernel`` directly, *bypassing*
    the eager ``2n <= 31`` guard, so ``repro.analysis`` can rediscover
    the packing bound.  The packed word itself never wraps uint32 (its
    envelope tops out at ``2^{2n} - 1``); what binds is the *output
    contract*: consumers (``core.luts`` tables, LUT kernels) treat the
    packed product as a non-negative int32 payload, so the claim is
    ``packed <= 2^31 - 1`` — first violated at ``n = 16``, which the
    auditor reports as a gating "contract" finding.
    """
    from repro.analysis.spec import TraceSpec, ValueRange, sds

    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))

    def fn(a, b):
        return pl.pallas_call(
            functools.partial(_kernel, n=n, t=t, approx=True, fix_to_1=True),
            grid=(1,),
            in_specs=[spec, spec],
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((block_rows, LANES), jnp.uint32),
            interpret=True,
        )(a, b)

    q = ValueRange.quantized(n)
    shape = (block_rows, LANES)
    return TraceSpec(
        name=f"kernel:seqmul_packed[n={n},t={t}]",
        fn=fn,
        args=[sds(shape, jnp.uint32), sds(shape, jnp.uint32)],
        ranges=[q, q],
        exact_products=True,
        out_ranges=[ValueRange(0.0, float(2**31 - 1), int_valued=True)],
        out_contract_reason=(
            "packed single-word product is consumed as a non-negative "
            "int32 LUT payload, requiring 2n <= 31"
        ),
    )


def audit_trace_words(*, n: int, t: int, block_rows: int = 8):
    """Static-audit contract for the two-word elementwise kernel: the
    (low, high) split must stay overflow-free for every n <= 16."""
    from repro.analysis.spec import TraceSpec, ValueRange, sds

    fn = functools.partial(
        _seqmul_words_jit, n=n, t=t, approx=True, fix_to_1=True,
        block_rows=block_rows, interpret=True,
    )
    q = ValueRange.quantized(n)
    shape = (block_rows * LANES,)
    return TraceSpec(
        name=f"kernel:seqmul_words[n={n},t={t}]",
        fn=fn,
        args=[sds(shape, jnp.uint32), sds(shape, jnp.uint32)],
        ranges=[q, q],
        exact_products=True,
    )
