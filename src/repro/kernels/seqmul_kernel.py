"""Pallas TPU kernel: tiled elementwise approximate sequential multiply.

The bit-serial datapath of the paper maps onto the TPU VPU as n iterations
of uint32 word ops over (block_rows, 128) VMEM tiles — the lane dimension
is the hardware's native 128, the sublane blocking is chosen so all live
tiles (two inputs, one output, loop state) stay well under VMEM.

The kernel body is the *same* split-word recurrence as the reference
(`core.seqmul.seq_mul_words_impl`); only the memory orchestration
(BlockSpec tiling, grid) is kernel-specific, so bit-exactness against the
oracle is structural and asserted in tests over shape/dtype/config sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK_ROWS = 64  # (64, 128) u32 tiles = 32 KiB per operand buffer


def _seqmul_body(a, b, *, n: int, t: int, approx: bool, fix_to_1: bool):
    m_t = jnp.uint32((1 << t) - 1)
    one = jnp.uint32(1)
    zero = jnp.zeros_like(a)

    def cycle(j, state):
        s_lsp, s_msp, c_ff, lo = state
        b_j = (b >> j.astype(jnp.uint32)) & one
        m = jnp.where(b_j.astype(bool), a, zero)
        aug_lsp = (s_lsp >> 1) | ((s_msp & one) << (t - 1))
        aug_msp = s_msp >> 1
        lsum = aug_lsp + (m & m_t)
        c_out = lsum >> t
        c_in = c_ff if approx else c_out
        msum = aug_msp + (m >> t) + c_in
        lo = lo | ((lsum & one) << j.astype(jnp.uint32))
        return lsum & m_t, msum, c_out, lo

    s_lsp, s_msp, c_last, lo = jax.lax.fori_loop(0, n, cycle, (zero, zero, zero, zero))
    lo = lo & jnp.uint32((1 << (n - 1)) - 1) if n > 1 else jnp.zeros_like(lo)
    if approx and fix_to_1:
        hit = c_last.astype(bool)
        lo = jnp.where(hit, jnp.uint32((1 << (n - 1)) - 1) if n > 1 else jnp.uint32(0), lo)
        s_lsp = jnp.where(hit, m_t, s_lsp)
        s_msp = jnp.where(hit, s_msp | one, s_msp)
    # packed 2n-bit product (valid for 2n <= 31)
    return lo + ((s_lsp + (s_msp << t)) << (n - 1))


def _kernel(a_ref, b_ref, o_ref, *, n, t, approx, fix_to_1):
    o_ref[...] = _seqmul_body(
        a_ref[...], b_ref[...], n=n, t=t, approx=approx, fix_to_1=fix_to_1
    )


@functools.partial(
    jax.jit,
    static_argnames=("n", "t", "approx", "fix_to_1", "block_rows", "interpret"),
)
def seqmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    n: int,
    t: int,
    approx: bool = True,
    fix_to_1: bool = True,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Elementwise approximate product of uint32 arrays (any shape).

    Flattens, pads to a (rows, 128) layout, launches a 1-D grid of
    (block_rows, 128) tiles, then restores the original shape.
    """
    if 2 * n > 31:
        raise ValueError("packed kernel supports 2n <= 31 bits")
    shape = a.shape
    flat = a.size
    rows = -(-max(flat, 1) // LANES)
    rows_pad = -(-rows // block_rows) * block_rows
    pad = rows_pad * LANES - flat

    def prep(x):
        x = jnp.asarray(x, jnp.uint32).reshape(-1)
        return jnp.pad(x, (0, pad)).reshape(rows_pad, LANES)

    a2, b2 = prep(a), prep(b)
    grid = (rows_pad // block_rows,)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, n=n, t=t, approx=approx, fix_to_1=fix_to_1),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANES), jnp.uint32),
        interpret=interpret,
    )(a2, b2)
    return out.reshape(-1)[:flat].reshape(shape)
