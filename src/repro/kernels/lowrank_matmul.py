"""Pallas TPU kernel: fused exact GEMM + low-rank error-correction GEMM.

Beyond-paper optimization (DESIGN.md §2): with E = approx - exact factored
as E ≈ U V^T (rank r), the approximate GEMM becomes

    C[i,j] = Σ_k a·b  +  Σ_k Σ_r (s_a U[|a|])[i,k,r] (s_b V[|b|])[k,j,r]
           = A @ B    +  Ue' @ Ve'        (Ue' (M, K·r), Ve' (K·r, N))

i.e. two MXU matmuls instead of per-element VPU gathers.  Fusing them in
one kernel keeps a single f32 accumulator tile in VMEM and reads the
operand tiles once — halving accumulator HBM traffic vs. running the two
GEMMs separately.

Operand embeddings (Ue, Ve) are gathered outside the kernel (O(M·K·r)
bytes, a one-time layout cost analogous to weight preprocessing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.engine.policy import resolve_interpret

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _kernel(a_ref, b_ref, ue_ref, ve_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    acc += jnp.dot(ue_ref[...], ve_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("rank", "bm", "bn", "bk", "interpret"))
def _lowrank_matmul_jit(
    a: jax.Array,  # (M, K) f32 — signed quantized integer values
    b: jax.Array,  # (K, N) f32
    ue: jax.Array,  # (M, K, r) f32 — s_a * U[|a|]
    ve: jax.Array,  # (K, N, r) f32 — s_b * V[|b|]
    *,
    rank: int,
    bm: int,
    bn: int,
    bk: int,
    interpret: bool,
) -> jax.Array:
    m_dim, k_dim = a.shape
    _, n_dim = b.shape
    # flatten (K, r) so the correction is a plain (M, K·r)x(K·r, N) GEMM;
    # K-blocking then walks both contractions in lock-step.
    ue2 = ue.reshape(m_dim, k_dim * rank)
    ve2 = jnp.swapaxes(ve, 0, 1).reshape(n_dim, k_dim * rank).T  # (K·r, N)

    def pad2(x, r, c):
        return jnp.pad(jnp.asarray(x, jnp.float32), ((0, -x.shape[0] % r), (0, -x.shape[1] % c)))

    ap = pad2(a, bm, bk)
    bp = pad2(b, bk, bn)
    uep = pad2(ue2, bm, bk * rank)
    vep = pad2(ve2, bk * rank, bn)
    mp, kp, np_ = ap.shape[0], ap.shape[1], bp.shape[1]

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, bk * rank), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk * rank, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(ap, bp, uep, vep)
    return out[:m_dim, :n_dim]


def lowrank_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    ue: jax.Array,
    ve: jax.Array,
    *,
    rank: int,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused exact + low-rank-correction GEMM (see module docstring).

    ``interpret=None`` resolves through the engine's shared backend policy.
    """
    return _lowrank_matmul_jit(
        a, b, ue, ve, rank=rank, bm=bm, bn=bn, bk=bk,
        interpret=resolve_interpret(interpret),
    )


def audit_trace(*, n: int = 8, t: int = 0, rank: int = 8, bm: int = DEFAULT_BM,
                bn: int = DEFAULT_BN, bk: int = DEFAULT_BK):
    """Static-audit contract for the lowrank GEMM (no execution).

    Float-valued by design (the SVD correction), so only carrier
    overflow and VMEM are provable — ``exact_products=False``.
    """
    del n, t
    from repro.analysis.spec import TraceSpec, sds

    fn = functools.partial(_lowrank_matmul_jit, rank=rank, bm=bm, bn=bn,
                           bk=bk, interpret=True)
    m_dim, k_dim, n_dim = bm, 2 * bk, bn
    return TraceSpec(
        name=f"kernel:lowrank_matmul[r={rank}]",
        fn=fn,
        args=[sds((m_dim, k_dim), jnp.float32), sds((k_dim, n_dim), jnp.float32),
              sds((m_dim, k_dim, rank), jnp.float32),
              sds((k_dim, n_dim, rank), jnp.float32)],
        exact_products=False,
    )
