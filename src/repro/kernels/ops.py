"""Compatibility shim over ``repro.engine`` (the old kernel entry points).

Historically this module owned the interpret policy, its own LUT/SVD
device caches, and a mode-string dispatch — all of that now lives in
``repro.engine`` (policy / artifacts / modes / dispatch).  These wrappers
pin ``backend="pallas"`` to preserve the old behavior of always running
the Pallas kernels (native on TPU, interpret elsewhere, per the shared
policy).  New code should call ``repro.engine.matmul`` /
``repro.engine.multiply`` directly.
"""

from __future__ import annotations

import jax

from repro.engine import dispatch as _engine
from repro.engine.policy import use_interpret  # noqa: F401  (re-export)

__all__ = ["use_interpret", "approx_multiply", "approx_matmul_kernel"]


def approx_multiply(
    a: jax.Array, b: jax.Array, *, n: int = 8, t: int = 4, fix_to_1: bool = True
) -> jax.Array:
    """Elementwise approximate product of uint32 magnitudes (Pallas)."""
    return _engine.multiply(
        a, b, n=n, t=t, approx=True, fix_to_1=fix_to_1, backend="pallas"
    )


def approx_matmul_kernel(
    x: jax.Array,
    w: jax.Array,
    *,
    n: int = 8,
    t: int = 4,
    fix_to_1: bool = True,
    mode: str = "bitexact",
    rank: int = 8,
) -> jax.Array:
    """f32 (M, K) @ (K, N) with approximate products, via Pallas kernels."""
    if mode not in ("bitexact", "lowrank"):
        raise ValueError(f"kernel modes are 'bitexact' | 'lowrank', got {mode!r}")
    return _engine.matmul(
        x, w, n=n, t=t, fix_to_1=fix_to_1, mode=mode, rank=rank, backend="pallas"
    )
