"""Public jit'd entry points for the approximate-arithmetic kernels.

Dispatch policy: on TPU the Pallas kernels compile natively; everywhere
else (this CPU container, unit tests) they run in ``interpret=True`` mode.
Set ``REPRO_FORCE_INTERPRET=0`` to force native lowering.

``approx_matmul_kernel`` is the framework-facing API: a drop-in f32 GEMM
whose scalar products follow the paper's segmented-carry-chain multiplier,
with the execution strategy selected by ``mode`` (see core.approx_matmul).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.approx_matmul import error_moments as _error_moments
from repro.core import luts, quantization
from repro.kernels.lowrank_matmul import lowrank_matmul_pallas
from repro.kernels.lut_matmul import lut_matmul_pallas
from repro.kernels.seqmul_kernel import seqmul_pallas

__all__ = ["use_interpret", "approx_multiply", "approx_matmul_kernel"]


def use_interpret() -> bool:
    env = os.environ.get("REPRO_FORCE_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def approx_multiply(
    a: jax.Array, b: jax.Array, *, n: int = 8, t: int = 4, fix_to_1: bool = True
) -> jax.Array:
    """Elementwise approximate product of uint32 magnitudes (Pallas)."""
    return seqmul_pallas(
        a, b, n=n, t=t, approx=True, fix_to_1=fix_to_1, interpret=use_interpret()
    )


@functools.lru_cache(maxsize=16)
def _lut_dev(n: int, t: int, fix_to_1: bool):
    with jax.ensure_compile_time_eval():  # cache concrete arrays, even under trace
        return jnp.asarray(luts.product_lut(n, t, fix_to_1=fix_to_1)).reshape(-1)


@functools.lru_cache(maxsize=16)
def _svd_dev(n: int, t: int, rank: int, fix_to_1: bool):
    u, v, _ = luts.svd_error_factors(n, t, rank, fix_to_1=fix_to_1)
    with jax.ensure_compile_time_eval():
        return jnp.asarray(u), jnp.asarray(v)


def approx_matmul_kernel(
    x: jax.Array,
    w: jax.Array,
    *,
    n: int = 8,
    t: int = 4,
    fix_to_1: bool = True,
    mode: str = "bitexact",
    rank: int = 8,
) -> jax.Array:
    """f32 (M, K) @ (K, N) with approximate products, via Pallas kernels."""
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    qx = quantization.calibrate_absmax(jax.lax.stop_gradient(x), bits=n)
    qw = quantization.calibrate_absmax(jax.lax.stop_gradient(w), bits=n)
    mx, sx = quantization.quantize(x, qx)
    mw, sw = quantization.quantize(w, qw)
    scale = qx.scale * qw.scale
    interp = use_interpret()

    if mode == "bitexact":
        out = lut_matmul_pallas(
            _lut_dev(n, t, fix_to_1),
            mx,
            sx.astype(jnp.float32),
            mw,
            sw.astype(jnp.float32),
            n=n,
            interpret=interp,
        )
        return out * scale
    if mode == "lowrank":
        u, v = _svd_dev(n, t, rank, fix_to_1)
        ax = mx.astype(jnp.float32) * sx.astype(jnp.float32)
        aw = mw.astype(jnp.float32) * sw.astype(jnp.float32)
        ue = u[mx.astype(jnp.int32)] * sx.astype(jnp.float32)[..., None]
        ve = v[mw.astype(jnp.int32)] * sw.astype(jnp.float32)[..., None]
        out = lowrank_matmul_pallas(ax, aw, ue, ve, rank=rank, interpret=interp)
        return out * scale
    raise ValueError(f"kernel modes are 'bitexact' | 'lowrank', got {mode!r}")
