"""Pallas flash attention with the approximate multiplier fused into the
QK and AV contractions.

Under a quality tier the attention projections already route through the
approximate-GEMM engine, but the score (``q @ k^T``) and value
(``p @ v``) contractions ran exact — approximating them via the engine
would materialize the (B, H, S, T) score/prob tensors in HBM, exactly
what flash attention exists to avoid.  This kernel applies the paper's
multiplier semantics *inside* the online-softmax tile loop:

``mode="lowrank"``
    scores  = (q_int @ k_int^T + Ue_q @ Ve_k^T) * scale_q * scale_k
    p @ v   = (p_int @ v_int  + U[p_int] @ Ve_v) * scale_p * scale_v
    with (U, V) the rank-r SVD factors of the error table — both terms
    are MXU matmuls; the operand embeddings are gathered once in HBM
    (like the fused lowrank GEMM), except ``U[p_int]`` which *must* be
    gathered in-kernel because the probabilities only exist there.

``mode="bitexact"``
    every scalar product in both contractions goes through the
    (2^n, 2^n) product LUT, pinned whole in VMEM (f32 — exact for the
    n <= 8 products it holds); gather-bound, the faithful oracle.

Probability quantization is *static*: p in [0, 1] after the online-max
subtraction, so ``p_int = round(p * (2^n - 1))`` with sign +1 and scale
``1/(2^n - 1)`` — no data-dependent calibration inside the kernel.  The
softmax statistics (m, l) stay exact f32: only the two contractions run
through the multiplier, mirroring a datapath where the MAC arrays are
approximate but the max/sum trees are not.

Gradients are straight-through at the attention level: backward reuses
the exact flash-attention backward kernels on the approximate forward's
(o, lse) residuals — the same policy the engine applies to
non-differentiable GEMM modes.

``approx_attention_reference`` mirrors the *blockwise* algorithm op for
op in pure jnp (same tile sizes, same update order), so interpret-mode
parity against the kernel is bit-exact and asserted in tests.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import quantization
from repro.engine import artifacts
from repro.engine.policy import resolve_interpret
from repro.kernels.flash_attention import NEG_INF, _block_mask, _bwd, _dot

__all__ = ["approx_flash_attention", "approx_attention_reference", "ATTN_MODES",
           "attn_tiles"]

ATTN_MODES = ("bitexact", "lowrank")
DEFAULT_BQ = 128
DEFAULT_BK = 128
# bitexact walks (bq, bk, hd) LUT-gather cubes (index + product + take's
# clip-mode copies); bk=64 keeps the traced peak liveness inside the
# 16 MiB VMEM budget at bq=128 — derived by repro.analysis, which
# certifies the (bq, bk) pairs attn_tiles returns.
BITEXACT_BK = 64
MAX_ATTN_N = 8  # both modes gather (2^n, ...) error/product tables


def attn_tiles(mode: str) -> tuple[int, int]:
    """VMEM-certified default (bq, bk) for ``mode``'s fused attention."""
    if mode == "bitexact":
        return DEFAULT_BQ, BITEXACT_BK
    return DEFAULT_BQ, DEFAULT_BK


# ---------------------------------------------------------- shared tile math
def _online_update(m, l, acc, s_int, allow, av_int, *,
                   qk_scale, pv_scale, scale, softcap, n):
    """One (q-block, k-block) step of the approximate online softmax.

    ``s_int`` is the integer-valued approximate score block (pre-scale),
    ``av_int(p_int)`` the integer-valued approximate ``p @ v`` block.
    Shared verbatim by the Pallas kernels and the blockwise reference, so
    interpret-mode parity is structural.
    """
    s = s_int * (qk_scale * scale)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(allow, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    p_int = jnp.round(p * ((1 << n) - 1)).astype(jnp.int32)
    acc_new = acc * corr[:, None] + av_int(p_int) * pv_scale
    return m_new, l_new, acc_new


def _lowrank_tile(qi, ki_t, vi, ueq, vek, vev, ut, *, rank):
    """(s_int, av_int) for one lowrank tile pair.

    qi (bq, hd), ki_t (bk, hd), vi (bk, hd): signed integer values (f32).
    ueq (bq, hd*r), vek (bk, hd*r): signed error embeddings of q and k.
    vev (bk, r*hd): V-side error embedding of v, (r, hd) C-flattened.
    ut (2^n, r): the U factor, gathered in-kernel by quantized p.
    """
    bq = qi.shape[0]
    bk, hd = vi.shape
    s_int = _dot(qi, ki_t, trans_b=True) + _dot(ueq, vek, trans_b=True)
    vev2 = vev.reshape(bk * rank, hd)

    def av_int(p_int):
        up = jnp.take(ut, p_int.reshape(-1), axis=0).reshape(bq, bk * rank)
        return _dot(p_int.astype(jnp.float32), vi) + _dot(up, vev2)

    return s_int, av_int


def _bitexact_tile(mq, sq, mk, sk, mv, sv, lut, *, n):
    """(s_int, av_int) for one bitexact tile pair: every scalar product is
    a product-LUT gather (the (bq, bk, hd) cube the GEMM LUT kernel also
    walks), signs applied as f32 outer factors."""
    bq, hd = mq.shape
    bk = mk.shape[0]
    base = jnp.int32(1 << n)
    idx = mq[:, None, :] * base + mk[None, :, :]  # (bq, bk, hd)
    prod = jnp.take(lut, idx.reshape(-1), axis=0).reshape(bq, bk, hd)
    s_int = (prod * (sq[:, None, :] * sk[None, :, :])).sum(axis=-1)

    def av_int(p_int):
        idx2 = p_int[:, :, None] * base + mv[None, :, :]
        prod2 = jnp.take(lut, idx2.reshape(-1), axis=0).reshape(bq, bk, hd)
        return (prod2 * sv[None, :, :]).sum(axis=1)

    return s_int, av_int


# ------------------------------------------------------------------- kernels
def _carry_init(o_ref, ml_ref):
    o_ref[...] = jnp.zeros_like(o_ref)
    ml_ref[0, 0, 0, :] = jnp.full((ml_ref.shape[-1],), NEG_INF, jnp.float32)
    ml_ref[0, 1, 0, :] = jnp.zeros((ml_ref.shape[-1],), jnp.float32)


def _carry_step(o_ref, ml_ref, qp, kp, sc, s_int, av_int,
                *, causal, window, softcap, scale, n, nk):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _():
        _carry_init(o_ref, ml_ref)

    allow = _block_mask(qp, kp, causal, window)
    m, l, acc = _online_update(
        ml_ref[0, 0, 0, :], ml_ref[0, 1, 0, :], o_ref[0, :, 0, :],
        s_int, allow, av_int,
        qk_scale=sc[0, 0], pv_scale=sc[0, 1], scale=scale,
        softcap=softcap, n=n,
    )
    ml_ref[0, 0, 0, :] = m
    ml_ref[0, 1, 0, :] = l
    o_ref[0, :, 0, :] = acc

    @pl.when(ki == nk - 1)
    def _():
        l_fin = jnp.maximum(ml_ref[0, 1, 0, :], 1e-30)
        o_ref[0, :, 0, :] = o_ref[0, :, 0, :] / l_fin[:, None]
        ml_ref[0, 0, 0, :] = ml_ref[0, 0, 0, :] + jnp.log(l_fin)


def _lowrank_kernel(qp_ref, kp_ref, sc_ref, qi_ref, ki_ref, vi_ref,
                    ueq_ref, vek_ref, vev_ref, ut_ref, o_ref, ml_ref,
                    *, causal, window, softcap, scale, n, rank, nk):
    s_int, av_int = _lowrank_tile(
        qi_ref[0, :, 0, :], ki_ref[0, :, 0, :], vi_ref[0, :, 0, :],
        ueq_ref[0, :, 0, :], vek_ref[0, :, 0, :], vev_ref[0, :, 0, :],
        ut_ref[...], rank=rank,
    )
    _carry_step(o_ref, ml_ref, qp_ref[0, :], kp_ref[0, :], sc_ref[...],
                s_int, av_int, causal=causal, window=window,
                softcap=softcap, scale=scale, n=n, nk=nk)


def _bitexact_kernel(qp_ref, kp_ref, sc_ref, mq_ref, sq_ref, mk_ref, sk_ref,
                     mv_ref, sv_ref, lut_ref, o_ref, ml_ref,
                     *, causal, window, softcap, scale, n, nk):
    s_int, av_int = _bitexact_tile(
        mq_ref[0, :, 0, :], sq_ref[0, :, 0, :],
        mk_ref[0, :, 0, :], sk_ref[0, :, 0, :],
        mv_ref[0, :, 0, :], sv_ref[0, :, 0, :],
        lut_ref[...].reshape(-1), n=n,
    )
    _carry_step(o_ref, ml_ref, qp_ref[0, :], kp_ref[0, :], sc_ref[...],
                s_int, av_int, causal=causal, window=window,
                softcap=softcap, scale=scale, n=n, nk=nk)


# ------------------------------------------------------------ operand prep
def _quant_signed(x, n):
    """Per-tensor sign-magnitude quantization; returns (mag u32, sign f32,
    signed integer values f32, scale)."""
    qp = quantization.calibrate_absmax(jax.lax.stop_gradient(x), bits=n)
    mag, sign = quantization.quantize(x, qp)
    sign = sign.astype(jnp.float32)
    return mag, sign, mag.astype(jnp.float32) * sign, qp.scale


def _prepare(mode, q, k, v, *, n, t, fix_to_1, rank):
    """Quantize operands and gather the HBM-side error artifacts.

    Returns (operands, scales) where ``scales = [[qk_scale, pv_scale]]``
    and ``operands`` is the mode-specific tuple fed to the kernel after
    padding.  p-quantization is static (scale ``1/(2^n - 1)``), so every
    data-dependent scale is resolved here, outside the kernel.
    """
    mq, sq, qi, scale_q = _quant_signed(q, n)
    mk, sk, ki, scale_k = _quant_signed(k, n)
    mv, sv, vi, scale_v = _quant_signed(v, n)
    scales = jnp.stack(
        [scale_q * scale_k, scale_v / jnp.float32((1 << n) - 1)]
    ).reshape(1, 2).astype(jnp.float32)
    if mode == "lowrank":
        u, vf, _ = artifacts.svd_factors(n, t, rank, fix_to_1)
        b, s, h, hd = q.shape
        tt, kv = k.shape[1], k.shape[2]
        ueq = (u[mq.astype(jnp.int32)] * sq[..., None]).reshape(b, s, h, hd * rank)
        vek = (vf[mk.astype(jnp.int32)] * sk[..., None]).reshape(b, tt, kv, hd * rank)
        # V-side embedding of v, (r, hd) C-flattened so the kernel's
        # (bk*r, hd) reshape walks rows as t*r + j — the layout the
        # in-kernel U[p_int] @ Ve_v contraction flattens against.
        vev = jnp.swapaxes(vf[mv.astype(jnp.int32)] * sv[..., None], -1, -2)
        vev = vev.reshape(b, tt, kv, rank * hd)
        return (qi, ki, vi, ueq, vek, vev, u.astype(jnp.float32)), scales
    # bitexact: products < 2^{2n} are exact in f32, so the LUT rides VMEM
    # as f32 and both gathers stay in the kernel.
    lut = artifacts.product_lut(n, t, fix_to_1).astype(jnp.float32)
    i32 = lambda a: a.astype(jnp.int32)
    return (i32(mq), sq, i32(mk), sk, i32(mv), sv, lut), scales


def _pad_seq(x, target, axis):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - x.shape[axis])
    return jnp.pad(x, pad)


# ------------------------------------------------------------------ forward
@functools.partial(
    jax.jit,
    static_argnames=("mode", "causal", "window", "softcap", "scale",
                     "n", "t", "fix_to_1", "rank", "bq", "bk", "interpret"),
)
def _approx_fwd(q, k, v, q_pos, k_pos, *, mode, causal, window, softcap,
                scale, n, t, fix_to_1, rank, bq, bk, interpret):
    b, s, h, hd = q.shape
    tt, kv = k.shape[1], k.shape[2]
    g = h // kv
    bq_, bk_ = min(bq, s), min(bk, tt)
    sp = pl.cdiv(s, bq_) * bq_
    tp = pl.cdiv(tt, bk_) * bk_
    nq, nk = sp // bq_, tp // bk_

    ops, scales = _prepare(mode, q, k, v, n=n, t=t, fix_to_1=fix_to_1, rank=rank)
    # Explicit padding to tile multiples: padded key slots carry
    # k_pos = -1 (masked to exactly zero probability, zero AV embedding),
    # padded query rows are sliced off below — no out-of-bounds blocks.
    q_side = lambda x: _pad_seq(x, sp, 1)
    k_side = lambda x: _pad_seq(x, tp, 1)
    if mode == "lowrank":
        qi, ki, vi, ueq, vek, vev, ut = ops
        ops_p = (q_side(qi), k_side(ki), k_side(vi),
                 q_side(ueq), k_side(vek), k_side(vev), ut)
        table = ut
        kernel = functools.partial(
            _lowrank_kernel, causal=causal, window=window, softcap=softcap,
            scale=scale, n=n, rank=rank, nk=nk)
        # (width, side) per operand: q-side blocks walk (qi_, h_), k-side
        # blocks walk (ki_, h_ // g) — the GQA head mapping.
        layout = (
            (hd, "q"), (hd, "k"), (hd, "k"),
            (hd * rank, "q"), (hd * rank, "k"), (rank * hd, "k"),
        )
    else:
        mq, sq, mk, sk, mv, sv, lut = ops
        ops_p = (q_side(mq), q_side(sq), k_side(mk), k_side(sk),
                 k_side(mv), k_side(sv), lut)
        table = lut
        kernel = functools.partial(
            _bitexact_kernel, causal=causal, window=window, softcap=softcap,
            scale=scale, n=n, nk=nk)
        layout = (
            (hd, "q"), (hd, "q"), (hd, "k"),
            (hd, "k"), (hd, "k"), (hd, "k"),
        )
    qp = _pad_seq(q_pos, sp, 1)
    kp = jnp.pad(k_pos, ((0, 0), (0, tp - tt)), constant_values=-1)

    in_specs = [
        pl.BlockSpec((1, bq_), lambda b_, h_, qi_, ki_: (b_, qi_)),
        pl.BlockSpec((1, bk_), lambda b_, h_, qi_, ki_: (b_, ki_)),
        pl.BlockSpec((1, 2), lambda b_, h_, qi_, ki_: (0, 0)),
    ]
    for w_, side in layout:
        if side == "q":
            in_specs.append(pl.BlockSpec(
                (1, bq_, 1, w_), lambda b_, h_, qi_, ki_: (b_, qi_, h_, 0)))
        else:
            in_specs.append(pl.BlockSpec(
                (1, bk_, 1, w_),
                lambda b_, h_, qi_, ki_: (b_, ki_, h_ // g, 0)))
    in_specs.append(pl.BlockSpec(table.shape, lambda b_, h_, qi_, ki_:
                                 (0,) * table.ndim))

    o, ml = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, bq_, 1, hd), lambda b_, h_, qi_, ki_: (b_, qi_, h_, 0)),
            pl.BlockSpec((1, 2, 1, bq_), lambda b_, h_, qi_, ki_: (b_, 0, h_, qi_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, sp, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, 2, h, sp), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, scales, *ops_p)
    return o[:, :s], ml[:, 0, :, :s]


# --------------------------------------------------------------- public API
@functools.partial(jax.custom_vjp, nondiff_argnums=tuple(range(5, 17)))
def approx_flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    mode: str = "lowrank",
    n: int = 8,
    t: int = 4,
    fix_to_1: bool = True,
    rank: int = 8,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: float = 1.0,
    bq: Optional[int] = None,
    bk: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flash attention with approximate QK and AV contractions.

    q (B, S, H, hd), k/v (B, T, KV, hd), positions (B, S)/(B, T);
    returns (B, S, H, hd) f32.  ``mode`` is ``"lowrank"`` or
    ``"bitexact"`` (n <= 8 — both gather (2^n, ...) tables).
    ``bq``/``bk`` default to the mode's VMEM-certified tiles
    (:func:`attn_tiles`).  Gradients are straight-through: the exact
    flash-attention backward runs on the approximate forward's (o, lse)
    residuals.
    """
    bq_d, bk_d = attn_tiles(mode)
    bq = bq_d if bq is None else bq
    bk = bk_d if bk is None else bk
    o, _ = _approx_fwd(
        q, k, v, q_pos, k_pos, mode=mode, causal=causal, window=window,
        softcap=softcap, scale=scale, n=n, t=t, fix_to_1=fix_to_1,
        rank=rank, bq=bq, bk=bk, interpret=resolve_interpret(interpret),
    )
    return o


def validate_attn_mode(mode: str, n: int) -> None:
    if mode not in ATTN_MODES:
        raise ValueError(
            f"approx attention supports modes {ATTN_MODES}, got {mode!r}")
    if n > MAX_ATTN_N:
        raise ValueError(
            f"approx attention gathers (2^n, ...) tables in VMEM, which "
            f"needs n <= {MAX_ATTN_N} (got n={n})")


def _vjp_fwd(q, k, v, q_pos, k_pos, mode, n, t, fix_to_1, rank,
             causal, window, softcap, scale, bq, bk, interpret):
    o, lse = _approx_fwd(
        q, k, v, q_pos, k_pos, mode=mode, causal=causal, window=window,
        softcap=softcap, scale=scale, n=n, t=t, fix_to_1=fix_to_1,
        rank=rank, bq=bq, bk=bk, interpret=resolve_interpret(interpret),
    )
    return o, (q, k, v, q_pos, k_pos, o, lse)


def _vjp_bwd(mode, n, t, fix_to_1, rank, causal, window, softcap, scale,
             bq, bk, interpret, res, do):
    # Straight-through at the attention level: exact backward kernels on
    # the approximate forward's residuals (same policy as the engine's
    # non-differentiable GEMM modes).
    return _bwd(causal, window, softcap, scale, bq, bk,
                resolve_interpret(interpret), res, do)


approx_flash_attention.defvjp(_vjp_fwd, _vjp_bwd)


# ---------------------------------------------------------------- reference
def approx_attention_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    mode: str = "lowrank",
    n: int = 8,
    t: int = 4,
    fix_to_1: bool = True,
    rank: int = 8,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: float = 1.0,
    bq: Optional[int] = None,
    bk: Optional[int] = None,
) -> jax.Array:
    """Pure-jnp mirror of the fused kernel's *blockwise* algorithm.

    Identical tile sizes, identical update order, identical ops
    (``_online_update`` / ``_lowrank_tile`` / ``_bitexact_tile`` are
    shared with the kernel bodies), so interpret-mode parity is
    bit-exact — the oracle the parity sweep asserts against.
    """
    b, s, h, hd = q.shape
    tt, kv = k.shape[1], k.shape[2]
    g = h // kv
    bq_d, bk_d = attn_tiles(mode)
    bq = bq_d if bq is None else bq
    bk = bk_d if bk is None else bk
    bq_, bk_ = min(bq, s), min(bk, tt)
    sp = -(-s // bq_) * bq_
    tp = -(-tt // bk_) * bk_

    ops, scales = _prepare(mode, q, k, v, n=n, t=t, fix_to_1=fix_to_1, rank=rank)
    qk_scale, pv_scale = scales[0, 0], scales[0, 1]
    q_side = lambda x: _pad_seq(x, sp, 1)
    k_side = lambda x: _pad_seq(x, tp, 1)
    qp = _pad_seq(q_pos, sp, 1)
    kp = jnp.pad(k_pos, ((0, 0), (0, tp - tt)), constant_values=-1)
    if mode == "lowrank":
        qi, ki, vi, ueq, vek, vev, ut = ops
        qi, ueq = q_side(qi), q_side(ueq)
        ki, vi, vek, vev = k_side(ki), k_side(vi), k_side(vek), k_side(vev)
    else:
        mq, sq, mk, sk, mv, sv, lut = ops
        lut = lut.reshape(-1)
        mq, sq = q_side(mq.astype(jnp.int32)), q_side(sq)
        mk, sk = k_side(mk.astype(jnp.int32)), k_side(sk)
        mv, sv = k_side(mv.astype(jnp.int32)), k_side(sv)

    out = jnp.zeros((b, sp, h, hd), jnp.float32)
    for b_ in range(b):
        for h_ in range(h):
            kvh = h_ // g
            for qi_ in range(sp // bq_):
                qs = slice(qi_ * bq_, (qi_ + 1) * bq_)
                m = jnp.full((bq_,), NEG_INF, jnp.float32)
                l = jnp.zeros((bq_,), jnp.float32)
                acc = jnp.zeros((bq_, hd), jnp.float32)
                for ki_ in range(tp // bk_):
                    ks = slice(ki_ * bk_, (ki_ + 1) * bk_)
                    if mode == "lowrank":
                        s_int, av_int = _lowrank_tile(
                            qi[b_, qs, h_], ki[b_, ks, kvh], vi[b_, ks, kvh],
                            ueq[b_, qs, h_], vek[b_, ks, kvh],
                            vev[b_, ks, kvh], ut, rank=rank)
                    else:
                        s_int, av_int = _bitexact_tile(
                            mq[b_, qs, h_], sq[b_, qs, h_],
                            mk[b_, ks, kvh], sk[b_, ks, kvh],
                            mv[b_, ks, kvh], sv[b_, ks, kvh], lut, n=n)
                    allow = _block_mask(qp[b_, qs], kp[b_, ks], causal, window)
                    m, l, acc = _online_update(
                        m, l, acc, s_int, allow, av_int,
                        qk_scale=qk_scale, pv_scale=pv_scale, scale=scale,
                        softcap=softcap, n=n)
                l_fin = jnp.maximum(l, 1e-30)
                out = out.at[b_, qs, h_].set(acc / l_fin[:, None])
    return out[:, :s]
