"""Pallas TPU flash attention (forward + backward, custom_vjp).

The XLA-lowered blockwise attention keeps every (bq × bk) score block in
HBM (logits, probs, selects) and hoists the position masks out of the
layer scan as multi-GB loop carries (EXPERIMENTS.md §Perf iteration 3).
This kernel keeps the online-softmax state in VMEM: per (batch, head,
q-block) the running (m, l, acc) live in the revisited output block, so
score blocks never round-trip to HBM and masks are recomputed from
positions in-register — the flash-attention transformation, tiled for
the MXU (block sizes multiples of 128).

Features: causal masking, sliding window, logit softcap (Gemma2), GQA
via an index-mapped KV head (k/v are *not* repeated in HBM — each query
head's BlockSpec points at its KV group), explicit positions (cache
slots with pos < 0 are masked).

Backward follows FlashAttention-2: forward additionally writes
L = m + log(l); backward recomputes probabilities blockwise with one
kernel for dq (grid over q blocks) and one for dk/dv (grid over k
blocks, accumulating across the GQA group).

Validated in interpret mode against the pure-jnp oracle in
``tests/test_flash_kernel.py``; native lowering targets TPU.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention", "flash_decode"]

NEG_INF = -2.3819763e38
DEFAULT_BQ = 512
DEFAULT_BK = 512


def _block_mask(qp, kp, causal, window):
    m = kp[None, :] >= 0
    if causal:
        m &= qp[:, None] >= kp[None, :]
    if window is not None:
        m &= qp[:, None] - kp[None, :] < window
    return m


def _dot(a, b, trans_b=False):
    dims = (((1,), (1 if trans_b else 0,)), ((), ()))
    return jax.lax.dot_general(a, b, dims, preferred_element_type=jnp.float32)


# ------------------------------------------------------------------ forward
def _fwd_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, o_ref, ml_ref,
                *, causal, window, softcap, scale, nk):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)
        ml_ref[0, 0, 0, :] = jnp.full((ml_ref.shape[-1],), NEG_INF, jnp.float32)  # m
        ml_ref[0, 1, 0, :] = jnp.zeros((ml_ref.shape[-1],), jnp.float32)  # l

    q = q_ref[0, :, 0, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = _dot(q, k, trans_b=True) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    allow = _block_mask(qp_ref[0, :], kp_ref[0, :], causal, window)
    s = jnp.where(allow, s, NEG_INF)

    m_prev = ml_ref[0, 0, 0, :]
    l_prev = ml_ref[0, 1, 0, :]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    ml_ref[0, 1, 0, :] = l_prev * corr + p.sum(axis=-1)
    ml_ref[0, 0, 0, :] = m_new
    o_ref[0, :, 0, :] = o_ref[0, :, 0, :] * corr[:, None] + _dot(p, v)

    @pl.when(ki == nk - 1)
    def _():
        l = jnp.maximum(ml_ref[0, 1, 0, :], 1e-30)
        o_ref[0, :, 0, :] = o_ref[0, :, 0, :] / l[:, None]
        # final L = m + log l (overwrites the m slot; l slot becomes garbage)
        ml_ref[0, 0, 0, :] = ml_ref[0, 0, 0, :] + jnp.log(l)


def _fwd(q, k, v, q_pos, k_pos, causal, window, softcap, scale, bq, bk, interpret):
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    bq, bk = min(bq, s), min(bk, t)
    nq, nk = pl.cdiv(s, bq), pl.cdiv(t, bk)

    o, ml = pl.pallas_call(
        functools.partial(_fwd_kernel, causal=causal, window=window,
                          softcap=softcap, scale=scale, nk=nk),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq), lambda b_, h_, qi, ki: (b_, qi)),
            pl.BlockSpec((1, bk), lambda b_, h_, qi, ki: (b_, ki)),
            pl.BlockSpec((1, bq, 1, hd), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b_, h_, qi, ki: (b_, ki, h_ // g, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b_, h_, qi, ki: (b_, ki, h_ // g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, 2, 1, bq), lambda b_, h_, qi, ki: (b_, 0, h_, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, 2, h, s), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, k_pos, q, k, v)
    lse = ml[:, 0]  # (B, H, S)
    return o, lse


# ----------------------------------------------------------------- backward
def _dq_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, dd_ref,
               dq_ref, *, causal, window, softcap, scale, nk):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    do = do_ref[0, :, 0, :].astype(jnp.float32)
    lse = lse_ref[0, 0, 0, :]
    dd = dd_ref[0, 0, 0, :]

    raw = _dot(q, k, trans_b=True) * scale
    if softcap:
        tanh_term = jnp.tanh(raw / softcap)
        s = tanh_term * softcap
    else:
        s = raw
    allow = _block_mask(qp_ref[0, :], kp_ref[0, :], causal, window)
    s = jnp.where(allow, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])  # (bq, bk)
    dp = _dot(do, v, trans_b=True)
    ds = p * (dp - dd[:, None])
    if softcap:
        ds = ds * (1.0 - tanh_term * tanh_term)
    ds = jnp.where(allow, ds, 0.0)
    dq_ref[0, :, 0, :] += _dot(ds, k) * scale


def _dkv_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, dd_ref,
                dk_ref, dv_ref, *, causal, window, softcap, scale, g, nq):
    gi = pl.program_id(3)
    qi = pl.program_id(4)

    @pl.when((gi == 0) & (qi == 0))
    def _():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    do = do_ref[0, :, 0, :].astype(jnp.float32)
    lse = lse_ref[0, 0, 0, :]
    dd = dd_ref[0, 0, 0, :]

    raw = _dot(q, k, trans_b=True) * scale  # (bq, bk)
    if softcap:
        tanh_term = jnp.tanh(raw / softcap)
        s = tanh_term * softcap
    else:
        s = raw
    allow = _block_mask(qp_ref[0, :], kp_ref[0, :], causal, window)
    s = jnp.where(allow, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    dv_ref[0, :, 0, :] += _dot(p.T, do)
    dp = _dot(do, v, trans_b=True)
    ds = p * (dp - dd[:, None])
    if softcap:
        ds = ds * (1.0 - tanh_term * tanh_term)
    ds = jnp.where(allow, ds, 0.0)
    dk_ref[0, :, 0, :] += _dot(ds.T, q) * scale


def _bwd(causal, window, softcap, scale, bq, bk, interpret, res, do):
    q, k, v, q_pos, k_pos, o, lse = res
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    bq_, bk_ = min(bq, s), min(bk, t)
    nq, nk = pl.cdiv(s, bq_), pl.cdiv(t, bk_)
    do = do.astype(jnp.float32)
    dd = jnp.einsum("bshd,bshd->bhs", do, o.astype(jnp.float32))  # (B,H,S)
    lse4 = lse[:, None]  # (B,1,H,S) -> blockspec (1,1,1,bq)
    dd4 = dd[:, None]

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, causal=causal, window=window,
                          softcap=softcap, scale=scale, nk=nk),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq_), lambda b_, h_, qi, ki: (b_, qi)),
            pl.BlockSpec((1, bk_), lambda b_, h_, qi, ki: (b_, ki)),
            pl.BlockSpec((1, bq_, 1, hd), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, bk_, 1, hd), lambda b_, h_, qi, ki: (b_, ki, h_ // g, 0)),
            pl.BlockSpec((1, bk_, 1, hd), lambda b_, h_, qi, ki: (b_, ki, h_ // g, 0)),
            pl.BlockSpec((1, bq_, 1, hd), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
            pl.BlockSpec((1, 1, 1, bq_), lambda b_, h_, qi, ki: (b_, 0, h_, qi)),
            pl.BlockSpec((1, 1, 1, bq_), lambda b_, h_, qi, ki: (b_, 0, h_, qi)),
        ],
        out_specs=pl.BlockSpec((1, bq_, 1, hd), lambda b_, h_, qi, ki: (b_, qi, h_, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, hd), jnp.float32),
        interpret=interpret,
    )(q_pos, k_pos, q, k, v, do, lse4, dd4)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, causal=causal, window=window,
                          softcap=softcap, scale=scale, g=g, nq=nq),
        grid=(b, kv, nk, g, nq),
        in_specs=[
            pl.BlockSpec((1, bq_), lambda b_, kv_, ki, gi, qi: (b_, qi)),
            pl.BlockSpec((1, bk_), lambda b_, kv_, ki, gi, qi: (b_, ki)),
            pl.BlockSpec((1, bq_, 1, hd),
                         lambda b_, kv_, ki, gi, qi: (b_, qi, kv_ * g + gi, 0)),
            pl.BlockSpec((1, bk_, 1, hd), lambda b_, kv_, ki, gi, qi: (b_, ki, kv_, 0)),
            pl.BlockSpec((1, bk_, 1, hd), lambda b_, kv_, ki, gi, qi: (b_, ki, kv_, 0)),
            pl.BlockSpec((1, bq_, 1, hd),
                         lambda b_, kv_, ki, gi, qi: (b_, qi, kv_ * g + gi, 0)),
            pl.BlockSpec((1, 1, 1, bq_),
                         lambda b_, kv_, ki, gi, qi: (b_, 0, kv_ * g + gi, qi)),
            pl.BlockSpec((1, 1, 1, bq_),
                         lambda b_, kv_, ki, gi, qi: (b_, 0, kv_ * g + gi, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk_, 1, hd), lambda b_, kv_, ki, gi, qi: (b_, ki, kv_, 0)),
            pl.BlockSpec((1, bk_, 1, hd), lambda b_, kv_, ki, gi, qi: (b_, ki, kv_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, kv, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, t, kv, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, k_pos, q, k, v, do, lse4, dd4)

    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


# --------------------------------------------------------------- public API
@functools.partial(
    jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10, 11)
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: float = 1.0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """q (B,S,H,hd), k/v (B,T,KV,hd), positions (B,S)/(B,T) -> (B,S,H,hd) f32."""
    o, _ = _fwd(q, k, v, q_pos, k_pos, causal, window, softcap, scale, bq, bk,
                interpret)
    return o


def _fwd_vjp(q, k, v, q_pos, k_pos, causal, window, softcap, scale, bq, bk,
             interpret):
    o, lse = _fwd(q, k, v, q_pos, k_pos, causal, window, softcap, scale, bq, bk,
                  interpret)
    return o, (q, k, v, q_pos, k_pos, o, lse)


def _bwd_vjp(causal, window, softcap, scale, bq, bk, interpret, res, do):
    return _bwd(causal, window, softcap, scale, bq, bk, interpret, res, do)


flash_attention.defvjp(_fwd_vjp, _bwd_vjp)


# ------------------------------------------------------------- flash decode
def _decode_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, o_ref, ml_ref,
                   *, window, softcap, scale, nk, g):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)
        ml_ref[0, 0, 0, :] = jnp.full((g,), NEG_INF, jnp.float32)
        ml_ref[0, 1, 0, :] = jnp.zeros((g,), jnp.float32)

    q = q_ref[0, 0, :, :].astype(jnp.float32)   # (g, hd) — the KV group's heads
    k = k_ref[0, :, 0, :].astype(jnp.float32)   # (bk, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = _dot(q, k, trans_b=True) * scale        # (g, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qp = qp_ref[0]                               # scalar decode position
    kp = kp_ref[0, :]
    allow = (kp >= 0) & (kp <= qp)
    if window is not None:
        allow &= qp - kp < window
    s = jnp.where(allow[None, :], s, NEG_INF)

    m_prev = ml_ref[0, 0, 0, :]
    l_prev = ml_ref[0, 1, 0, :]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    ml_ref[0, 1, 0, :] = l_prev * corr + p.sum(axis=-1)
    ml_ref[0, 0, 0, :] = m_new
    o_ref[0, 0, :, :] = o_ref[0, 0, :, :] * corr[:, None] + _dot(p, v)

    @pl.when(ki == nk - 1)
    def _():
        l = jnp.maximum(ml_ref[0, 1, 0, :], 1e-30)
        o_ref[0, 0, :, :] = o_ref[0, 0, :, :] / l[:, None]


def flash_decode(
    q: jax.Array,      # (B, H, hd) — one new token per sequence
    k: jax.Array,      # (B, T, KV, hd) full cache
    v: jax.Array,
    q_pos: jax.Array,  # (B,) int32 decode positions
    k_pos: jax.Array,  # (B, T) int32 (-1 = unwritten slot)
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: float = 1.0,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Decode-step attention with the KV cache streamed through VMEM.

    The grid iterates (batch, kv-head, key-block); each kv head's g query
    heads form the row dim of the MXU tile, so GQA needs no HBM repeat.
    Returns (B, H, hd) f32.
    """
    b, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    bk = min(bk, t)
    nk = pl.cdiv(t, bk)
    qg = q.reshape(b, kv, g, hd)

    o, _ = pl.pallas_call(
        functools.partial(_decode_kernel, window=window, softcap=softcap,
                          scale=scale, nk=nk, g=g),
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, kv_, ki: (b_,)),
            pl.BlockSpec((1, bk), lambda b_, kv_, ki: (b_, ki)),
            pl.BlockSpec((1, 1, g, hd), lambda b_, kv_, ki: (b_, kv_, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b_, kv_, ki: (b_, ki, kv_, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b_, kv_, ki: (b_, ki, kv_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, kv_, ki: (b_, kv_, 0, 0)),
            pl.BlockSpec((1, 2, 1, g), lambda b_, kv_, ki: (b_, 0, kv_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, kv, g, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, 2, kv, g), jnp.float32),
        ],
        interpret=interpret,
    )(q_pos, k_pos, qg, k, v)
    return o.reshape(b, h, hd)
