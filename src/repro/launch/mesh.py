"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run
must set XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; ×2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices this host actually has: (data=n, model=1)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


class HW:
    """TPU v5e-class hardware constants for the roofline terms."""

    PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
    HBM_BW = 819e9  # B/s per chip
    ICI_BW = 50e9  # B/s per link (per-chip collective bandwidth proxy)
    VMEM_BYTES = 128 * 1024 * 1024
