"""ShapeDtypeStruct input stand-ins + sharding specs for every
(architecture × shape × step-kind) cell.

``input_specs(cfg, shape)`` mirrors the shannon/kernels pattern: weak-
type-correct, shardable, zero device allocation.  ``batch_specs`` /
``state_shardings`` / ``cache_shardings`` produce the NamedSharding trees
the dry-run lowers against.

Cache sharding heuristic (degrades per-dim via ``resolve_spec`` when a
dimension doesn't divide the mesh axis):
  trailing 4 dims  (B, S, KV, hd) or (B, H, P, N) -> (DP, TP, None, None)
    — shards the KV-cache *sequence* axis (flash-decode) or the SSM head
      axis over the model axis, and batch over data.
  trailing 3 dims  (B, K-1, C)                    -> (DP, None, TP)
  trailing 2 dims  (B, W)                         -> (DP, TP)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import DP, TP, mesh_axis_sizes, param_specs, resolve_spec

__all__ = [
    "ENC_MEM_LEN_DECODE",
    "input_specs",
    "batch_shardings",
    "state_shardings",
    "cache_shardings",
    "params_shardings",
]

# encoder-memory length for enc-dec *decode* cells (source is fixed while
# the decoder streams); train/prefill cells use src_len == seq_len.
ENC_MEM_LEN_DECODE = 4096


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Batch ShapeDtypeStructs for a *train or prefill* step."""
    gb, s = shape.global_batch, shape.seq_len
    out: dict = {}
    if cfg.is_encdec:
        out["tokens"] = _sds((gb, s), jnp.int32)
        out["src_embeds"] = _sds((gb, s, cfg.d_model), cfg.dtype)
        out["src_pos"] = _sds((gb, s), jnp.int32)
    elif cfg.frontend:  # vlm: precomputed patch embeddings for the stream
        out["embeds"] = _sds((gb, s, cfg.d_model), cfg.dtype)
        out["tokens"] = _sds((gb, s), jnp.int32)
    else:
        out["tokens"] = _sds((gb, s), jnp.int32)
    if shape.kind == "train":
        out["labels"] = _sds((gb, s), jnp.int32)
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    gb = shape.global_batch
    return {"token": _sds((gb, 1), jnp.int32), "pos": _sds((), jnp.int32)}


# ------------------------------------------------------------- shardings
def _named(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def batch_shardings(batch_tree: Any, mesh) -> Any:
    sizes = mesh_axis_sizes(mesh)

    def one(x):
        spec = (DP,) + (None,) * (x.ndim - 1) if x.ndim else ()
        return _named(mesh, resolve_spec(spec, x.shape, sizes))

    return jax.tree_util.tree_map(one, batch_tree)


def params_shardings(params_tree: Any, mesh, *, fsdp: bool = True) -> Any:
    return jax.tree_util.tree_map(
        lambda s: _named(mesh, s), param_specs(params_tree, mesh, fsdp=fsdp)
    )


def cache_shardings(cache_tree: Any, mesh) -> Any:
    sizes = mesh_axis_sizes(mesh)

    def one(x):
        nd = x.ndim
        if nd >= 4:
            spec = (None,) * (nd - 4) + (DP, TP, None, None)
        elif nd == 3:
            spec = (DP, None, TP)
        elif nd == 2:
            spec = (DP, TP)
        else:
            spec = (None,) * nd
        return _named(mesh, resolve_spec(spec, x.shape, sizes))

    return jax.tree_util.tree_map(one, cache_tree)


def state_shardings(state_shapes: Any, mesh, *, fsdp: bool = True) -> Any:
    """Shardings for a TrainState shape tree (params + mirrored opt)."""
    repl = _named(mesh, P())
    p_sh = params_shardings(state_shapes.params, mesh, fsdp=fsdp)

    def mirror(tree):
        # mu/nu have the params' structure; _Q8 leaves (code, scale) would
        # need their own layout — the dry-run baseline uses 32-bit states.
        return jax.tree_util.tree_map(
            lambda s, x: s if x.ndim else repl, p_sh, tree
        )

    return state_shapes._replace(
        params=p_sh,
        opt=state_shapes.opt._replace(
            step=repl, mu=mirror(state_shapes.opt.mu), nu=mirror(state_shapes.opt.nu)
        ),
        comp=None,
        rng=repl,
        step=repl,
    )
