"""Batched serving driver: prefill + decode loop over a request queue.

A static-batch continuous-batching-lite scheduler: requests arrive with
different prompt lengths, are padded into the prefill batch, decoded
together, and finished rows are retired (replaced from the queue) at
re-batch boundaries.  Demonstrates the serve_step path the decode dry-run
cells lower, on a reduced config on CPU.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 16 --batch 4 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import apply_approx, get_config
from repro.engine import modes as engine_modes
from repro.models.registry import build_model
from repro.train.steps import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--approx-mode", default=None, choices=engine_modes.list_modes())
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.approx_mode:
        cfg = apply_approx(cfg, mode=args.approx_mode)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    max_seq = args.prompt_len + args.gen
    mem_len = args.prompt_len if cfg.is_encdec else 0
    prefill = jax.jit(make_prefill_step(model, max_seq, mem_len=mem_len))
    decode = jax.jit(make_decode_step(model), donate_argnums=1)

    rng = np.random.default_rng(args.seed)
    queue = [
        rng.integers(0, cfg.vocab_size, size=rng.integers(4, args.prompt_len + 1))
        for _ in range(args.requests)
    ]
    done = 0
    tokens_out = 0
    t0 = time.perf_counter()
    while queue:
        batch_reqs = [queue.pop(0) for _ in range(min(args.batch, len(queue)))]
        b = len(batch_reqs)
        toks = np.zeros((b, args.prompt_len), np.int32)
        for i, r in enumerate(batch_reqs):
            toks[i, -len(r):] = r  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.is_encdec:
            batch["src_embeds"] = jnp.asarray(
                rng.standard_normal((b, args.prompt_len, cfg.d_model)), jnp.float32
            )
            batch["src_pos"] = jnp.arange(args.prompt_len, dtype=jnp.int32)[None].repeat(b, 0)
        caches, logits = prefill(params, batch)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        for g in range(args.gen):
            logits, caches = decode(params, caches, tok, jnp.int32(args.prompt_len + g))
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            tokens_out += b
        done += b
    dt = time.perf_counter() - t0
    print(f"served {done} requests, {tokens_out} tokens in {dt:.2f}s "
          f"({tokens_out/dt:.1f} tok/s on {len(jax.devices())} device(s))")


if __name__ == "__main__":
    main()
