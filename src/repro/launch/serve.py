"""Batched serving driver: prefill + decode loop over a request queue.

A static-batch continuous-batching-lite scheduler: requests arrive with
different prompt lengths, are padded into the prefill batch, decoded
together, and finished rows are retired (replaced from the queue) at
re-batch boundaries.  Demonstrates the serve_step path the decode dry-run
cells lower, on a reduced config on CPU.

The request loop itself is the importable :func:`serve_loop`, which
returns a :class:`ServeStats` instead of printing — the
``serve_throughput`` benchmark suite drives it directly; this module's
``main`` is the CLI wrapper.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 16 --batch 4 --gen 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import apply_approx, get_config
from repro.engine import modes as engine_modes
from repro.models.registry import build_model
from repro.train.steps import make_decode_step, make_prefill_step

__all__ = ["ServeStats", "serve_loop", "main"]


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """What one serve run measured (all wall times in seconds)."""

    requests: int
    tokens_out: int
    wall_s: float
    prefill_s: float  # total time in prefill across batches
    decode_s: float  # total time in the decode loops
    batch_latencies_s: tuple  # per-batch wall time, prefill through retire
    devices: int

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def requests_per_s(self) -> float:
        return self.requests / self.wall_s if self.wall_s > 0 else 0.0

    def summary(self) -> str:
        return (
            f"served {self.requests} requests, {self.tokens_out} tokens in "
            f"{self.wall_s:.2f}s ({self.tokens_per_s:.1f} tok/s on "
            f"{self.devices} device(s))"
        )


def serve_loop(
    model,
    params,
    *,
    requests: int = 16,
    batch_size: int = 4,
    prompt_len: int = 32,
    gen: int = 32,
    seed: int = 0,
) -> ServeStats:
    """Run the static-batch prefill+decode loop; return its stats.

    Builds (and jits) the prefill/decode pair for ``prompt_len + gen``,
    synthesizes ``requests`` random prompts of varying length, serves them
    in batches of ``batch_size``, and times every stage.  Greedy decoding;
    deterministic for a fixed ``seed``.
    """
    cfg = model.cfg
    max_seq = prompt_len + gen
    mem_len = prompt_len if cfg.is_encdec else 0
    prefill = jax.jit(make_prefill_step(model, max_seq, mem_len=mem_len))
    decode = jax.jit(make_decode_step(model), donate_argnums=1)

    rng = np.random.default_rng(seed)
    queue = [
        rng.integers(0, cfg.vocab_size, size=rng.integers(4, prompt_len + 1))
        for _ in range(requests)
    ]
    done = 0
    tokens_out = 0
    prefill_s = 0.0
    decode_s = 0.0
    batch_latencies: list[float] = []
    t0 = time.perf_counter()
    while queue:
        t_batch = time.perf_counter()
        batch_reqs = [queue.pop(0) for _ in range(min(batch_size, len(queue)))]
        b = len(batch_reqs)
        toks = np.zeros((b, prompt_len), np.int32)
        for i, r in enumerate(batch_reqs):
            toks[i, -len(r):] = r  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.is_encdec:
            batch["src_embeds"] = jnp.asarray(
                rng.standard_normal((b, prompt_len, cfg.d_model)), jnp.float32
            )
            batch["src_pos"] = jnp.arange(prompt_len, dtype=jnp.int32)[None].repeat(b, 0)
        caches, logits = prefill(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter()
        prefill_s += t_prefill - t_batch
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        for g in range(gen):
            logits, caches = decode(params, caches, tok, jnp.int32(prompt_len + g))
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            tokens_out += b
        jax.block_until_ready(tok)
        decode_s += time.perf_counter() - t_prefill
        batch_latencies.append(time.perf_counter() - t_batch)
        done += b
    wall = time.perf_counter() - t0
    return ServeStats(
        requests=done,
        tokens_out=tokens_out,
        wall_s=wall,
        prefill_s=prefill_s,
        decode_s=decode_s,
        batch_latencies_s=tuple(batch_latencies),
        devices=len(jax.devices()),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--approx-mode", default=None, choices=engine_modes.list_modes())
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.approx_mode:
        cfg = apply_approx(cfg, mode=args.approx_mode)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    stats = serve_loop(
        model,
        params,
        requests=args.requests,
        batch_size=args.batch,
        prompt_len=args.prompt_len,
        gen=args.gen,
        seed=args.seed,
    )
    print(stats.summary())


if __name__ == "__main__":
    main()
