"""Serving CLI: thin driver over the ``repro.serve`` subsystem.

The request loop itself lives in ``repro.serve`` (docs/serving.md): a
continuous-batching scheduler with slot-based KV-cache admission —
finished rows are retired and queued requests admitted *per decode step*
(single-row prefill scattered into the freed slot; surviving rows are
never re-prefilled), with per-row position vectors so left-padded short
prompts decode at their true positions.  ``--scheduler static`` selects
the legacy static-batch loop (the measured baseline).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 16 --batch 4 --gen 32

``--loop open`` switches to arrival-clocked admission: requests are
drawn from a ``--workload`` preset with real arrival times and only
become admissible once the (virtual or wall) clock passes them, with a
pluggable ``--policy`` (static / slo-adaptive / reject) deciding
admission and the pool's accuracy tier per step:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --loop open --workload bursty --policy slo-adaptive \
      --slo-ttft-ms 50 --requests 64 --batch 4 --gen 8

``serve_loop`` and ``ServeStats`` stay importable here for backward
compatibility; ``serve_loop`` now delegates to
:func:`repro.serve.static_serve_loop` over a synthesized queue.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.registry import apply_approx, get_config
from repro.distributed.sharding import data_parallel_mesh
from repro.engine import config as engine_config
from repro.engine import modes as engine_modes
from repro.models.registry import build_model
from repro.serve import (
    SelfSpeculative,
    ServeStats,
    continuous_serve_loop,
    get_policy,
    static_serve_loop,
    supports_continuous,
    synth_requests,
)
from repro.serve.policy import POLICIES
from repro.serve.stats import percentile
from repro.serve.workload import PRESETS, generate, preset_spec

__all__ = ["ServeStats", "serve_loop", "main"]


def serve_loop(
    model,
    params,
    *,
    requests: int = 16,
    batch_size: int = 4,
    prompt_len: int = 32,
    gen: int = 32,
    seed: int = 0,
) -> ServeStats:
    """Legacy entry point: static-batch loop over a synthesized queue.

    Kept for existing callers; new code should build a request list
    (``repro.serve.synth_requests`` or real prompts) and call
    ``static_serve_loop`` / ``continuous_serve_loop`` directly.
    """
    queue = synth_requests(
        requests, prompt_len=prompt_len, gen=gen,
        vocab_size=model.cfg.vocab_size, seed=seed, vary_budget=False,
    )
    result = static_serve_loop(
        model, params, queue,
        batch_size=batch_size, prompt_len=prompt_len, gen=gen,
        seed=seed, warmup=False,
    )
    return result.stats


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--approx-mode", default=None, choices=engine_modes.list_modes())
    ap.add_argument("--quality-tier", default=None,
                    choices=engine_config.list_tiers(),
                    help="accuracy tier for the run: the engine.config "
                         "controller resolves each GEMM class to the cheapest "
                         "splitting point meeting the tier's error budget; "
                         "requests are tagged with the tier and checked at "
                         "admission (mutually exclusive with --approx-mode)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheduler", default=None,
                    choices=("continuous", "static"),
                    help="continuous: per-step retirement/admission (the default "
                         "where supported); static: the legacy re-batch-at-drain "
                         "loop (auto-selected for encoder-decoder and "
                         "recurrent-state archs, which continuous rejects)")
    ap.add_argument("--vary-budget", action="store_true",
                    help="draw per-request budgets in [1, gen] instead of gen")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="retire a row early when it emits this token id")
    ap.add_argument("--data-parallel", action="store_true",
                    help="shard the decode batch over a ('data',) device mesh "
                         "when multiple devices are available")
    ap.add_argument("--loop", default="closed", choices=("closed", "open"),
                    help="closed: drain a pre-filled queue (the legacy mode); "
                         "open: arrival-clocked admission — requests become "
                         "admissible only once their workload arrival time "
                         "passes (continuous scheduler only)")
    ap.add_argument("--workload", default="bursty", choices=sorted(PRESETS),
                    help="open loop: traffic preset supplying the arrival "
                         "clock and length tails (ignored for --loop closed)")
    ap.add_argument("--policy", default=None, choices=sorted(POLICIES),
                    help="admission policy for --loop open: static keeps the "
                         "bit-match oracle, slo-adaptive degrades the pool "
                         "tier under load, reject sheds when the queue grows")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="stamp a TTFT SLO (ms) on every open-loop request; "
                         "enables slo attainment in the summary")
    ap.add_argument("--step-time-ms", type=float, default=10.0,
                    help="virtual-clock cost of one exact decode step (open "
                         "loop; tiers scale it by their cycle factor)")
    ap.add_argument("--clock", default="virtual", choices=("virtual", "wall"),
                    help="open loop: deterministic virtual clock (default) or "
                         "real sleeping wall clock")
    ap.add_argument("--strategy", default="greedy",
                    choices=("greedy", "speculative"),
                    help="decode strategy (continuous scheduler only): greedy "
                         "one-token rounds, or self-speculative rounds — k "
                         "draft-tier proposal steps verified by one batched "
                         "forward on the verify tier; output bit-matches "
                         "greedy decode on the verify engine")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="speculative: draft tokens proposed per round")
    ap.add_argument("--draft-tier", default="draft",
                    choices=engine_config.list_tiers(),
                    help="speculative: accuracy tier proposing draft tokens")
    ap.add_argument("--verify-tier", default=None,
                    choices=engine_config.list_tiers(),
                    help="speculative: tier whose engine verifies (default: "
                         "the pool's own tier)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.approx_mode and args.quality_tier:
        ap.error("--approx-mode and --quality-tier are mutually exclusive "
                 "(the tier owns the mode)")
    if args.approx_mode:
        cfg = apply_approx(cfg, mode=args.approx_mode)
    if args.quality_tier:
        print(f"# {engine_config.resolve_tier(args.quality_tier).describe()}")

    scheduler = args.scheduler
    if scheduler is None:
        scheduler = "continuous" if supports_continuous(cfg) else "static"
        if scheduler == "static":
            print(f"# {cfg.name}: auto-selected --scheduler static "
                  f"(continuous supports attention-only decoder stacks)")
    if args.data_parallel and scheduler != "continuous":
        ap.error("--data-parallel only applies to --scheduler continuous")
    if args.loop == "open" and scheduler != "continuous":
        ap.error("--loop open requires --scheduler continuous")
    if args.policy is not None and args.loop != "open":
        ap.error("--policy only applies to --loop open (closed-loop "
                 "admission is the implicit static policy)")
    if args.strategy == "speculative" and scheduler != "continuous":
        ap.error("--strategy speculative requires --scheduler continuous")

    strategy = None
    if args.strategy == "speculative":
        strategy = SelfSpeculative(
            k=args.spec_k, draft_tier=args.draft_tier,
            verify_tier=args.verify_tier,
        )
        verify = args.verify_tier or args.quality_tier or "exact"
        est = engine_config.accept_rate_estimate(args.draft_tier, verify)
        print(f"# speculative: k={args.spec_k} draft={args.draft_tier} "
              f"verify={verify}, accept-rate lower bound {est:.1%} "
              f"(engine_config.accept_rate_estimate)")

    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    run_kwargs = {}
    if args.loop == "open":
        spec = preset_spec(
            args.workload, requests=args.requests, prompt_len=args.prompt_len,
            max_new=args.gen, vocab_size=cfg.vocab_size,
            slo_ttft_s=args.slo_ttft_ms / 1e3 if args.slo_ttft_ms else None,
        )
        draw = generate(spec, seed=args.seed)
        queue = list(draw.requests)
        run_kwargs = dict(
            arrivals_s=list(draw.arrivals_s),
            policy=get_policy(args.policy or "static"),
            step_time_s=args.step_time_ms / 1e3,
            clock=args.clock,
        )
        print(f"# open loop: {args.workload} preset, offered "
              f"{draw.offered_rps:.1f} rps, policy "
              f"{run_kwargs['policy'].name}")
    else:
        queue = synth_requests(
            args.requests, prompt_len=args.prompt_len, gen=args.gen,
            vocab_size=cfg.vocab_size, seed=args.seed,
            vary_budget=args.vary_budget, eos_id=args.eos_id,
            quality=args.quality_tier,
        )
    if scheduler == "continuous":
        mesh = data_parallel_mesh(args.batch) if args.data_parallel else None
        result = continuous_serve_loop(
            model, params, queue,
            batch_size=args.batch, prompt_len=args.prompt_len,
            max_new=args.gen, mesh=mesh, quality=args.quality_tier,
            strategy=strategy, **run_kwargs,
        )
    else:
        result = static_serve_loop(
            model, params, queue,
            batch_size=args.batch, prompt_len=args.prompt_len, gen=args.gen,
            seed=args.seed, quality=args.quality_tier,
        )
    print(result.stats.summary())
    ar = result.stats.accept_rate
    if ar is not None:
        print(f"# speculative accept: {result.stats.spec_accepted}/"
              f"{result.stats.spec_proposed} draft tokens ({ar:.1%}), "
              f"{result.stats.spec_rolled_back} rolled back over "
              f"{result.stats.spec_rounds} speculated rounds")
    lat = result.stats.request_latencies_s
    if lat:
        print(
            f"per-request latency p50 {1e3 * percentile(lat, 50):.0f}ms "
            f"p95 {1e3 * percentile(lat, 95):.0f}ms over "
            f"{len(lat)} requests"
        )
    for sw in result.tier_switches:
        print(f"# tier switch @ step {sw.step} t={sw.now_s:.3f}s: "
              f"{sw.from_tier} -> {sw.to_tier} ({sw.reason})")


if __name__ == "__main__":
    main()
