"""Loop-aware roofline analysis of compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` visits a ``while`` body **once**, so
for scanned-layer models (every model here) it undercounts FLOPs, bytes,
and collectives by the layer trip count.  This module re-derives the
three roofline inputs from the compiled HLO *with correct loop
multiplicities*:

  - **flops**: every ``dot`` (wherever it lives, including inside fusion
    bodies) contributes ``2 × |result| × K``, multiplied by the product
    of surrounding loop trip counts (taken from the ``known_trip_count``
    backend config XLA attaches to each while op).
  - **bytes**: an HBM-traffic model — each *top-level* op in a
    sequential computation moves (operands + result) bytes; fusion
    internals are free (they live in registers/VMEM); DUS moves only the
    updated slice; aliasing/metadata ops (bitcast, tuple, gte, ...) are
    free.  This mirrors how a perfectly-fused TPU program touches HBM.
  - **collective bytes**: per-kind sums of collective result buffers ×
    multiplicity.  Per-op records keep the source ``op_name`` metadata so
    redundant collectives (same tensor gathered twice) are attributable
    to model code during the perf pass.

All numbers are per-device (the module is the per-partition program).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

__all__ = ["Analysis", "OpRecord", "analyze_hlo", "xla_cost_dict", "COLLECTIVE_OPS"]


def xla_cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions.

    Older jax returns a one-element list of dicts (per executable),
    newer jax returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# ops that move no HBM bytes themselves
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "iota", "partition-id",
    "replica-id", "rng-get-and-update-state", "domain", "opt-barrier",
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_LINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^(\(?.*?\)?)\s*([a-z][a-z0-9\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def type_bytes(ty: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(ty):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(ty: str) -> Optional[list[int]]:
    m = _SHAPE_RE.search(ty)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    ty: str
    rhs: str  # full right-hand side text (attrs included)
    operands: list


@dataclasses.dataclass
class Computation:
    name: str
    ops: list  # of Op
    symbols: dict  # name -> type string
    params: list  # parameter names, in signature order
    is_entry: bool = False


@dataclasses.dataclass
class OpRecord:
    computation: str
    name: str
    opcode: str
    bytes: int
    flops: float
    mult: float
    meta: str  # op_name metadata if present


@dataclasses.dataclass
class Analysis:
    flops: float
    bytes: float
    collective_bytes: dict  # kind -> bytes (mult-weighted)
    collectives: list  # OpRecords for collectives
    dots: list  # OpRecords for dots
    byte_ops: list  # OpRecords for the heaviest HBM-traffic ops
    trip_counts: dict  # while op name -> n

    def top_bytes(self, k: int = 10) -> list:
        return sorted(self.byte_ops, key=lambda r: -r.bytes * r.mult)[:k]

    @property
    def collective_total(self) -> float:
        return float(sum(self.collective_bytes.values()))

    def top_collectives(self, k: int = 10) -> list:
        return sorted(self.collectives, key=lambda r: -r.bytes * r.mult)[:k]

    def top_dots(self, k: int = 10) -> list:
        return sorted(self.dots, key=lambda r: -r.flops * r.mult)[:k]


def _parse_computations(text: str) -> dict:
    comps: dict = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{") and ("->" in line) and (
                line.startswith("%") or line.startswith("ENTRY")
            ):
                m = _COMP_HDR_RE.match(line)
                if not m:
                    continue
                cur = Computation(
                    name=m.group(1), ops=[], symbols={}, params=[],
                    is_entry=line.startswith("ENTRY"),
                )
                # signature params: "name: type" pairs
                sig = m.group(2)
                for pm in re.finditer(r"([\w.\-]+)\s*:\s*(\(?[a-z0-9\[\],{}/* ]+\)?)", sig):
                    cur.symbols[pm.group(1)] = pm.group(2)
                    cur.params.append(pm.group(1))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OPCODE_RE.match(rhs)
        if not om:
            continue
        ty, opcode = om.group(1).strip(), om.group(2)
        # operand names: within the first (...) after the opcode
        paren = rhs[om.end() - 1:]
        depth = 0
        end = 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = _OPERAND_RE.findall(paren[:end])
        op = Op(name=name, opcode=opcode, ty=ty, rhs=rhs, operands=operands)
        cur.symbols[name] = ty
        cur.ops.append(op)
    return comps


def _dot_flops(op: Op, comp: Computation) -> float:
    out_dims = _shape_dims(op.ty) or []
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    k = 1
    cm = _CONTRACT_RE.search(op.rhs)
    if cm and op.operands:
        lhs_ty = comp.symbols.get(op.operands[0], "")
        lhs_dims = _shape_dims(lhs_ty)
        if lhs_dims is not None:
            for idx in (int(x) for x in cm.group(1).split(",") if x):
                if idx < len(lhs_dims):
                    k *= lhs_dims[idx]
    return 2.0 * out_elems * k


def _effective_consumers(fused: Computation, name: str) -> list:
    """Consumers of ``name``, looking through convert/bitcast/copy chains.

    XLA CPU legalizes bf16 dynamic-update-slice via a full f32 convert
    round-trip of the target buffer; a TPU build updates in place.  The
    traffic model charges the *semantic* op, not the legalization."""
    users: dict = defaultdict(list)
    for op in fused.ops:
        for o in op.operands:
            users[o].append(op)
    out, seen, frontier = [], set(), [name]
    while frontier:
        cur = frontier.pop()
        for op in users.get(cur, ()):
            if op.name in seen:
                continue
            seen.add(op.name)
            if op.opcode in ("convert", "bitcast", "copy"):
                frontier.append(op.name)
            else:
                out.append((cur, op))  # (operand-as-seen, consuming op)
    return out


def _fusion_root(fused: Computation):
    """Root op, unwrapped through convert/bitcast/copy."""
    if not fused.ops:
        return None
    defs = {op.name: op for op in fused.ops}
    root = fused.ops[-1]
    while root.opcode in ("convert", "bitcast", "copy") and root.operands:
        nxt = defs.get(root.operands[0])
        if nxt is None:
            break
        root = nxt
    return root


def _fusion_param_bytes(fused: Computation) -> dict:
    """Per-parameter-index HBM traffic inside a fused computation.

    A fusion parameter that is only consumed by ``dynamic-slice`` ops
    reads just the slices (the classic scan pattern: slice layer i out of
    stacked (L, ...) weights); a parameter that is only the target of a
    ``dynamic-update-slice`` is aliased (0 bytes); any other use reads
    the full operand.  Convert/bitcast/copy chains are looked through.
    """
    # parameter name -> index: explicit parameter(i) ops, else signature order
    pidx: dict = {}
    for op in fused.ops:
        if op.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", op.rhs)
            if m:
                pidx[op.name] = int(m.group(1))
    if not pidx:
        pidx = {name: i for i, name in enumerate(fused.params)}
    out: dict = {}
    for pname, idx in pidx.items():
        consumers = _effective_consumers(fused, pname)
        if consumers and all(c.opcode == "dynamic-slice" for _, c in consumers):
            out[idx] = sum(type_bytes(c.ty) for _, c in consumers)
        elif consumers and all(
            c.opcode == "dynamic-update-slice" and c.operands and c.operands[0] == via
            for via, c in consumers
        ):
            out[idx] = 0  # aliased DUS target: update counted via operand 1
        else:
            out[idx] = None  # full read
    return out


def _op_bytes(op: Op, comp: Computation, comps: Optional[dict] = None) -> int:
    """HBM traffic of a top-level op (operands + result)."""
    if op.opcode in _FREE_OPS:
        return 0
    if op.opcode == "dynamic-update-slice":
        # aliases the big buffer; traffic = update slice in + out
        if len(op.operands) >= 2:
            upd = comp.symbols.get(op.operands[1], "")
            return 2 * type_bytes(upd)
        return 0
    if op.opcode == "dynamic-slice":
        return 2 * type_bytes(op.ty)
    if op.opcode == "fusion" and comps is not None:
        fm = _CALLS_RE.search(op.rhs)
        fused = comps.get(fm.group(1)) if fm else None
        if fused is not None:
            per_param = _fusion_param_bytes(fused)
            total = 0
            root = _fusion_root(fused)
            if root is not None and root.opcode == "dynamic-update-slice":
                upd = fused.symbols.get(root.operands[1], "") if len(root.operands) > 1 else ""
                total += 2 * type_bytes(upd)
            else:
                total += type_bytes(op.ty)
            for i, o in enumerate(op.operands):
                pb = per_param.get(i)
                total += type_bytes(comp.symbols.get(o, "")) if pb is None else pb
            return total
    total = type_bytes(op.ty)
    for o in op.operands:
        total += type_bytes(comp.symbols.get(o, ""))
    return total


def analyze_hlo(text: str) -> Analysis:
    comps = _parse_computations(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # ---- call graph: (callee, mult, kind) edges
    edges: dict = defaultdict(list)  # caller -> [(callee, mult, kind)]
    trip_counts: dict = {}
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "while":
                n = 1
                tm = _TRIP_RE.search(op.rhs)
                if tm:
                    n = int(tm.group(1))
                trip_counts[op.name] = n
                bm, cm = _BODY_RE.search(op.rhs), _COND_RE.search(op.rhs)
                if bm:
                    edges[comp.name].append((bm.group(1), n, "loop"))
                if cm:
                    edges[comp.name].append((cm.group(1), n + 1, "loop"))
            elif op.opcode == "fusion":
                fm = _CALLS_RE.search(op.rhs)
                if fm:
                    edges[comp.name].append((fm.group(1), 1, "fusion"))
            elif op.opcode == "call":
                fm = re.search(r"to_apply=%([\w.\-]+)", op.rhs)
                if fm:
                    edges[comp.name].append((fm.group(1), 1, "call"))
            elif op.opcode == "conditional":
                bm = _BRANCHES_RE.search(op.rhs)
                if bm:
                    for callee in _OPERAND_RE.findall(bm.group(1)):
                        edges[comp.name].append((callee, 1, "call"))
            # NOTE: to_apply of reduce/scatter/sort = scalar computations;
            # deliberately not traversed (negligible, would distort counts).

    # ---- multiplicities (computation may be reached via several paths)
    mult: dict = defaultdict(float)
    fusion_internal: set = set()

    def walk(name: str, m: float, via_fusion: bool) -> None:
        mult[name] += m
        if via_fusion:
            fusion_internal.add(name)
        for callee, em, kind in edges.get(name, ()):
            if callee in comps:
                walk(callee, m * em, via_fusion or kind == "fusion")

    walk(entry.name, 1.0, False)

    # ---- totals
    flops = 0.0
    bytes_total = 0.0
    coll: dict = defaultdict(float)
    coll_recs: list = []
    dot_recs: list = []
    byte_recs: list = []
    meta_re = re.compile(r'op_name="([^"]*)"')

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        internal = comp.name in fusion_internal
        for op in comp.ops:
            base = op.opcode.replace("-start", "")
            if op.opcode in ("dot", "convolution"):
                f = _dot_flops(op, comp)
                flops += m * f
                mm = meta_re.search(op.rhs)
                dot_recs.append(OpRecord(
                    comp.name, op.name, op.opcode, _op_bytes(op, comp, comps), f, m,
                    mm.group(1) if mm else "",
                ))
            if base in COLLECTIVE_OPS and not op.opcode.endswith("-done"):
                b = type_bytes(op.ty)
                coll[base] += m * b
                mm = meta_re.search(op.rhs)
                coll_recs.append(OpRecord(
                    comp.name, op.name, base, b, 0.0, m, mm.group(1) if mm else ""
                ))
            if not internal:
                b = _op_bytes(op, comp, comps)
                bytes_total += m * b
                if b * m > 0:
                    mm = meta_re.search(op.rhs)
                    byte_recs.append(OpRecord(
                        comp.name, op.name, op.opcode, b, 0.0, m,
                        mm.group(1) if mm else "",
                    ))

    return Analysis(
        flops=flops,
        bytes=bytes_total,
        collective_bytes=dict(coll),
        collectives=coll_recs,
        dots=dot_recs,
        byte_ops=byte_recs,
        trip_counts=trip_counts,
    )
