import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the production mesh (single-pod 16×16 = 256
chips, or multi-pod 2×16×16 = 512), lowers the appropriate step
(train_step for train shapes, prefill/decode for serving shapes) against
ShapeDtypeStruct inputs with the framework's sharding rules, compiles it,
and extracts:

  - memory_analysis()  — per-device bytes: proves the cell fits HBM,
  - hlo_analysis       — loop-multiplicity-correct per-device HLO FLOPs,
    HBM-traffic bytes, and per-kind collective bytes parsed from the
    post-SPMD compiled HLO (XLA's cost_analysis() counts while bodies
    once; see launch/hlo_analysis.py),
  - cost_analysis()    — kept as a secondary record,

and derives the three roofline terms (EXPERIMENTS.md §Roofline):

  compute  = FLOPs_per_device / PEAK_FLOPS
  memory   = bytes_per_device / HBM_BW
  collect. = collective_bytes_per_device / ICI_BW

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results.jsonl
"""

import argparse
import functools
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, TrainConfig
from repro.configs.registry import get_config, list_archs, shapes_for
from repro.distributed.sharding import mesh_context
from repro.launch.hlo_analysis import analyze_hlo, xla_cost_dict
from repro.launch.mesh import HW, make_production_mesh
from repro.launch import specs as S
from repro.models.registry import build_model
from repro.train import steps as tsteps


def model_flops(cfg, shape, kind: str) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference) useful-FLOPs floor."""
    # active params: embeddings excluded (lookup), MoE counts top-k experts
    d, L = cfg.d_model, cfg.num_layers
    attn = 0
    if cfg.num_heads:
        attn = d * cfg.head_dim * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    if cfg.num_experts:
        ffn = 3 * d * cfg.moe_d_ff * cfg.num_experts_per_tok
    elif cfg.d_ff:
        ffn = 3 * d * cfg.d_ff
    else:
        ffn = 0
    if "rglru" in cfg.layer_pattern:
        w = cfg.lru_width
        rec = 2 * d * w + 2 * w * w + w * d
        n_rec = sum(k == "rglru" for k in cfg.layer_pattern) / len(cfg.layer_pattern)
        n_att = 1 - n_rec
        per_layer = n_rec * (rec + ffn) + n_att * (attn + ffn)
    elif "ssd" in cfg.layer_pattern:
        di = cfg.d_inner or 2 * d
        per_layer = d * (2 * di + 2 * cfg.ssm_state + (cfg.ssm_heads or 1)) + di * d
    else:
        per_layer = attn + ffn
    n_active = L * per_layer
    if cfg.is_encdec:
        n_active += cfg.encoder_layers * (attn + ffn) + L * attn  # enc + cross
    n_active += cfg.d_model * cfg.vocab_size  # lm head matmul is real compute
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def _step_kind(shape) -> str:
    return shape.kind


def lower_cell(arch: str, shape_name: str, multi_pod: bool, *, extra: dict | None = None,
               accum: int = 1, fsdp: bool = True, approx_mode: str | None = None,
               quality_tier: str | None = None):
    """Lower + compile one cell; returns the result record."""
    cfg = get_config(arch, **(extra or {}))
    if approx_mode and quality_tier:
        raise ValueError("approx_mode and quality_tier are mutually exclusive")
    if approx_mode:
        from repro.configs.registry import apply_approx
        cfg = apply_approx(cfg, mode=approx_mode)
    elif quality_tier:
        from repro.configs.registry import apply_quality
        cfg = apply_quality(cfg, quality_tier)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    kind = _step_kind(shape)
    t0 = time.time()

    with mesh_context(mesh):
        if kind == "train":
            tcfg = TrainConfig(grad_accum=accum)
            state_shapes = jax.eval_shape(
                lambda: tsteps.init_train_state(model, tcfg, jax.random.PRNGKey(0))
            )
            state_sh = S.state_shardings(state_shapes, mesh, fsdp=fsdp)
            batch_shapes = S.input_specs(cfg, shape)
            batch_sh = S.batch_shardings(batch_shapes, mesh)
            step = tsteps.make_train_step(model, tcfg)
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh), donate_argnums=0
            ).lower(state_shapes, batch_shapes)
        else:
            params_shapes = jax.eval_shape(
                lambda: model.init_params(jax.random.PRNGKey(0))
            )
            params_sh = S.params_shardings(params_shapes, mesh)
            if kind == "prefill":
                batch_shapes = S.input_specs(cfg, shape)
                batch_sh = S.batch_shardings(batch_shapes, mesh)
                prefill = tsteps.make_prefill_step(
                    model, shape.seq_len, mem_len=shape.seq_len if cfg.is_encdec else 0
                )
                lowered = jax.jit(prefill, in_shardings=(params_sh, batch_sh)).lower(
                    params_shapes, batch_shapes
                )
            else:  # decode
                mem_len = S.ENC_MEM_LEN_DECODE if cfg.is_encdec else 0
                cache_shapes = jax.eval_shape(
                    functools.partial(
                        model.init_caches,
                        shape.global_batch,
                        shape.seq_len,
                        jnp.dtype(cfg.dtype),
                        mem_len=mem_len,
                    )
                )
                cache_sh = S.cache_shardings(cache_shapes, mesh)
                dspecs = S.decode_input_specs(cfg, shape)
                tok_sh = S.batch_shardings(dspecs["token"], mesh)
                repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
                decode = tsteps.make_decode_step(model)
                lowered = jax.jit(
                    decode,
                    in_shardings=(params_sh, cache_sh, tok_sh, repl),
                    donate_argnums=1,
                ).lower(params_shapes, cache_shapes, dspecs["token"], dspecs["pos"])

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = xla_cost_dict(compiled)
    hlo = compiled.as_text()
    ana = analyze_hlo(hlo)
    coll = {k: float(v) for k, v in ana.collective_bytes.items()}
    coll_total = ana.collective_total
    chips = 512 if multi_pod else 256

    flops = ana.flops
    bytes_acc = ana.bytes
    t_compute = flops / HW.PEAK_FLOPS
    t_memory = bytes_acc / HW.HBM_BW
    t_coll = coll_total / HW.ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, kind)
    mf_per_dev = mf / chips

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips,
        "kind": kind,
        "grad_accum": accum if kind == "train" else None,
        "fsdp": fsdp,
        "approx_mode": approx_mode,
        "quality_tier": quality_tier,
        "ok": True,
        "compile_s": round(time.time() - t0, 1),
        "mem": {
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "arg_bytes": getattr(mem, "argument_size_in_bytes", None),
            "out_bytes": getattr(mem, "output_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "flops_per_dev": flops,
        "bytes_per_dev": bytes_acc,
        "xla_cost_flops": float(cost.get("flops", 0.0)),  # loop-undercounted
        "collective_bytes_per_dev": coll,
        "top_collectives": [
            {"op": r.opcode, "bytes": r.bytes, "mult": r.mult, "src": r.meta[:120]}
            for r in ana.top_collectives(6)
        ],
        "top_bytes": [
            {"op": r.opcode, "bytes": r.bytes, "mult": r.mult, "src": r.meta[:120]}
            for r in ana.top_bytes(6)
        ],
        "collective_total": coll_total,
        "terms_s": terms,
        "dominant": dominant,
        "model_flops_total": mf,
        "useful_ratio": (mf_per_dev / flops) if flops else None,
        "step_time_bound_s": max(terms.values()),
        "roofline_fraction": (mf_per_dev / HW.PEAK_FLOPS) / max(max(terms.values()), 1e-30),
    }
    return rec


# Per-arch settings for the optimized (--perf) matrix run, chosen by the
# hillclimb: microbatching for dense trains (activation temp / accum),
# sequence-sharded residuals + accum=1 for kimi (FSDP re-gathers grow with
# accum at 1T params — measured tradeoff in EXPERIMENTS.md §Perf).
PERF_SETTINGS = {
    "kimi-k2-1t-a32b": dict(accum=1, extra={"seq_shard_residuals": True}),
    "granite-moe-1b-a400m": dict(accum=4),
}
DEFAULT_TRAIN_ACCUM = 8


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches for train cells")
    ap.add_argument("--perf", action="store_true",
                    help="apply the per-arch PERF_SETTINGS (optimized matrix)")
    ap.add_argument("--fsdp", choices=["on", "off"], default="on",
                    help="ZeRO-3 param/opt sharding over the data axis")
    ap.add_argument("--approx-mode", default=None, help="deploy the paper technique")
    ap.add_argument("--quality-tier", default=None,
                    help="accuracy tier (engine.config): lower the cell with "
                         "the controller-resolved per-GEMM-class (n, t, mode)")
    args = ap.parse_args()
    if args.approx_mode and args.quality_tier:
        ap.error("--approx-mode and --quality-tier are mutually exclusive "
                 "(the tier owns the mode)")

    cells = []
    if args.all:
        for arch in list_archs():
            cfg = get_config(arch)
            for sname in shapes_for(cfg):
                cells.append((arch, sname))
    else:
        cells.append((args.arch, args.shape))
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_f = open(args.out, "a") if args.out else None
    n_fail = 0
    for arch, sname in cells:
        for mp in meshes:
            try:
                accum, extra = args.accum, None
                if args.perf and SHAPES[sname].kind == "train":
                    st = PERF_SETTINGS.get(arch, {})
                    accum = st.get("accum", DEFAULT_TRAIN_ACCUM)
                    extra = st.get("extra")
                rec = lower_cell(arch, sname, mp, extra=extra, accum=accum,
                                 fsdp=args.fsdp == "on",
                                 approx_mode=args.approx_mode,
                                 quality_tier=args.quality_tier)
            except Exception as e:  # noqa: BLE001 — report, continue
                rec = {
                    "arch": arch, "shape": sname,
                    "mesh": "multi" if mp else "single",
                    "ok": False, "error": f"{type(e).__name__}: {e}",
                }
                traceback.print_exc()
                n_fail += 1
            line = json.dumps(rec)
            print(line, flush=True)
            if out_f:
                out_f.write(line + "\n")
                out_f.flush()
    if out_f:
        out_f.close()
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
