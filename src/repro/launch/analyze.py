"""Static kernel audit CLI: certify the full mode × tier matrix.

Runs the three analysis passes (`repro.analysis`) — interval/overflow
abstract interpretation, gather bounds, VMEM budget — over every entry
of ``analysis.audit.matrix_entries()`` and prints one verdict row per
traced configuration.  Nothing is executed: every verdict comes from
abstract evaluation of the kernel jaxpr.

Exit status is non-zero if *any* entry is uncertified, which makes this
the gating ``static-analysis`` CI job.

Usage:
  python -m repro.launch.analyze                  # table + exit status
  python -m repro.launch.analyze --report out.json
  python -m repro.launch.analyze --markdown       # docs/kernels.md table
"""

from __future__ import annotations

import argparse
import json
import sys


def _fmt_mib(nbytes: int) -> str:
    return f"{nbytes / 2**20:.2f}"


def _peak_vmem(result) -> int:
    return max((e["total_bytes"] for e in result.vmem), default=0)


def _print_table(results) -> None:
    rows = [("kernel", "family", "n", "t", "VMEM MiB", "verdict")]
    for r in results:
        verdict = "certified" if r.certified else "UNPROVEN"
        rows.append((r.name, r.family, str(r.n), str(r.t),
                     _fmt_mib(_peak_vmem(r)) if r.vmem else "-", verdict))
    widths = [max(len(row[i]) for row in rows) for i in range(len(rows[0]))]
    for i, row in enumerate(rows):
        print("  ".join(cell.ljust(w) for cell, w in zip(row, widths)))
        if i == 0:
            print("  ".join("-" * w for w in widths))


def _print_findings(results) -> None:
    for r in results:
        if r.certified:
            continue
        print(f"\n{r.name}: NOT certified")
        for f in r.findings:
            flag = "gating" if f.gating else "note"
            print(f"  [{flag}] {f.kind}: {f.message}")


def _markdown_table(report: dict) -> str:
    """The machine-generated VMEM table spliced into docs/kernels.md."""
    budget = report["vmem_budget_bytes"]
    lines = [
        "<!-- BEGIN GENERATED VMEM TABLE"
        " (python -m repro.launch.analyze --markdown) -->",
        "| Traced kernel | family | n | t | peak VMEM (MiB) | "
        f"budget {budget // 2**20} MiB | verdict |",
        "|---|---|---|---|---|---|---|",
    ]
    for e in report["entries"]:
        peak = max((v["total_bytes"] for v in e["vmem"]), default=0)
        within = all(v["within_budget"] for v in e["vmem"])
        lines.append(
            f"| `{e['name']}` | {e['family']} | {e['n']} | {e['t']} | "
            f"{_fmt_mib(peak) if e['vmem'] else '—'} | "
            f"{'within' if within else '**over**'} | "
            f"{'certified' if e['certified'] else '**unproven**'} |"
        )
    lines.append(
        "<!-- END GENERATED VMEM TABLE — do not edit by hand; regenerate "
        "with `python -m repro.launch.analyze --markdown` -->"
    )
    return "\n".join(lines)


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.launch.analyze",
        description="statically certify every (mode, n, t) kernel configuration",
    )
    parser.add_argument("--report", metavar="PATH",
                        help="write the machine-readable JSON report here")
    parser.add_argument("--markdown", action="store_true",
                        help="print the docs/kernels.md VMEM table and exit")
    args = parser.parse_args(argv)

    from repro.analysis import audit

    if args.markdown:
        rep = audit.report()
        print(_markdown_table(rep))
        return 0 if rep["all_certified"] else 1

    results = audit.audit_matrix()
    _print_table(results)
    bad = [r for r in results if not r.certified]
    _print_findings(results)
    print(f"\n{len(results)} configurations audited, "
          f"{len(results) - len(bad)} certified, {len(bad)} unproven")
    if args.report:
        from repro.analysis.vmem import VMEM_BUDGET_BYTES

        rep = {
            "vmem_budget_bytes": VMEM_BUDGET_BYTES,
            "all_certified": not bad,
            "entries": [r.to_dict() for r in results],
        }
        with open(args.report, "w") as fh:
            json.dump(rep, fh, indent=2)
        print(f"report written to {args.report}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
