"""Soak CLI: long traffic-realistic runs against the serving schedulers.

Thin driver over :func:`repro.serve.soak.run_soak` (docs/serving.md
§Soak testing): picks a workload preset from ``repro.serve.workload``,
streams it through the continuous (or static) scheduler in bounded
windows, prints a per-window audit line, and exits non-zero on any
invariant violation — slot leaks, lost/duplicate serves, per-row
write-position violations, TTFT-p99 drift beyond ``--drift-limit``, or
a failed parity spot-check.

  # the documented long local soak (~20k requests)
  PYTHONPATH=src python -m repro.launch.soak --arch qwen3-0.6b --reduced \
      --workload bursty --requests 20000 --batch 8 --prompt-len 16 --gen 8 \
      --window 1024 --spot-check 8 --drift-limit 50

  # CI runs the ~2k-request version of the same (gating soak-smoke job)

  # open-loop clocked admission with SLO-adaptive tier degradation
  PYTHONPATH=src python -m repro.launch.soak --arch qwen3-0.6b --reduced \
      --workload bursty --loop open --policy slo-adaptive --slo-ttft-ms 50 \
      --requests 256 --batch 4 --window 64

``--json`` writes the report's summary row plus the per-window audits,
seed included, so a red run reproduces from the artifact alone.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax

from repro.configs.registry import get_config
from repro.engine import config as engine_config
from repro.models.registry import build_model
from repro.serve.policy import POLICIES
from repro.serve.soak import run_soak
from repro.serve.strategy import SelfSpeculative
from repro.serve.workload import PRESETS, preset_spec

__all__ = ["main"]


def _parse_tier_mix(text):
    """``"balanced=3,none=1"`` -> ((\"balanced\", 3.0), (None, 1.0))."""
    if not text:
        return ()
    mix = []
    for part in text.split(","):
        name, _, weight = part.partition("=")
        name = name.strip()
        mix.append((None if name in ("none", "") else name,
                    float(weight) if weight else 1.0))
    return tuple(mix)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="serve the reduced() smoke config")
    ap.add_argument("--workload", default="bursty", choices=sorted(PRESETS),
                    help="traffic preset (arrival process + length tails)")
    ap.add_argument("--requests", type=int, default=20000)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--window", type=int, default=1024,
                    help="requests per bounded-memory audit window")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scheduler", default="continuous",
                    choices=("continuous", "static"))
    ap.add_argument("--loop", default="closed", choices=("closed", "open"),
                    help="closed: each window drains as a pre-filled queue; "
                         "open: arrival-clocked admission against the "
                         "window's arrival times (continuous only)")
    ap.add_argument("--policy", default=None, choices=sorted(POLICIES),
                    help="admission policy (open loop): static / "
                         "slo-adaptive / reject; default static")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="stamp a TTFT SLO (ms) on every request so the "
                         "report carries slo attainment")
    ap.add_argument("--step-time-ms", type=float, default=10.0,
                    help="virtual-clock cost of one exact decode step "
                         "(open loop)")
    ap.add_argument("--clock", default="virtual", choices=("virtual", "wall"),
                    help="open loop: deterministic virtual clock (default) "
                         "or real sleeping wall clock")
    ap.add_argument("--quality-tier", default=None,
                    choices=engine_config.list_tiers(),
                    help="pool accuracy tier; tier-tagged requests are "
                         "checked against it at admission")
    ap.add_argument("--strategy", default="greedy",
                    choices=("greedy", "speculative"),
                    help="decode strategy (continuous only); speculative "
                         "pools still pass the parity spot-checks because "
                         "speculative output bit-matches plain decode, and "
                         "presets with a spec_fraction (churn/bursty) tag a "
                         "request fraction to exercise mid-stream switching")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="speculative: draft tokens proposed per round")
    ap.add_argument("--draft-tier", default="draft",
                    choices=engine_config.list_tiers(),
                    help="speculative: accuracy tier proposing draft tokens")
    ap.add_argument("--verify-tier", default=None,
                    choices=engine_config.list_tiers(),
                    help="speculative: tier whose engine verifies (default: "
                         "the pool's own tier)")
    ap.add_argument("--tier-mix", default="",
                    help="weighted request tier tags, e.g. 'balanced=3,none=1' "
                         "(tags must match --quality-tier or be none)")
    ap.add_argument("--drift-limit", type=float, default=50.0,
                    help="max allowed later-window TTFT p99 / first-window p99 "
                         "(<= 0 disables the drift gate)")
    ap.add_argument("--spot-check", type=int, default=4,
                    help="request ids re-served alone/unpadded and bit-compared")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the report (summary row + per-window audits)")
    args = ap.parse_args(argv)

    strategy = None
    if args.strategy == "speculative":
        if args.scheduler != "continuous":
            ap.error("--strategy speculative requires --scheduler continuous")
        strategy = SelfSpeculative(
            k=args.spec_k, draft_tier=args.draft_tier,
            verify_tier=args.verify_tier,
        )

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(args.seed))
    spec = preset_spec(
        args.workload, requests=args.requests, prompt_len=args.prompt_len,
        max_new=args.gen, vocab_size=cfg.vocab_size,
        tier_mix=_parse_tier_mix(args.tier_mix),
        slo_ttft_s=args.slo_ttft_ms / 1e3 if args.slo_ttft_ms else None,
    )

    def progress(w):
        tail = f"{1e3 * w.ttft_p99_s:.0f}ms" if w.ttft_p99_s is not None else "n/a"
        flag = "" if not w.violations else f"  !! {'; '.join(w.violations)}"
        print(f"# window {w.index:4d}: {w.requests} reqs, {w.tokens_out} toks, "
              f"{w.slot_utilization:.0%} util, ttft p99 {tail}{flag}", flush=True)

    report = run_soak(
        model, params, spec,
        batch_size=args.batch, seed=args.seed, window_size=args.window,
        scheduler=args.scheduler, quality=args.quality_tier,
        drift_limit=args.drift_limit if args.drift_limit > 0 else None,
        spot_check=args.spot_check, progress=progress,
        loop=args.loop, policy=args.policy,
        step_time_s=args.step_time_ms / 1e3, clock=args.clock,
        strategy=strategy,
    )

    print(report.describe())
    if args.json:
        doc = {
            "summary": report.summary_row(),
            "windows": [dataclasses.asdict(w) for w in report.windows],
            "violations": list(report.violations),
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True, default=float)
            f.write("\n")
        print(f"# wrote {args.json}")
    for v in report.violations:
        print(f"VIOLATION: {v}", file=sys.stderr)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
