"""End-to-end training driver.

Runs on whatever devices exist (CPU: 1 device; a pod: the production
mesh) — the sharding rules degrade per-dimension, so the same entry point
serves the smoke run and the real launch.

Examples:
  # ~100M-param model, a few hundred steps on CPU
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 300 --batch 8 --seq 128

  # paper technique on, bit-exact approximate MLPs
  PYTHONPATH=src python -m repro.launch.train --arch paper-multiplier \
      --reduced --steps 100 --approx-mode bitexact
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig
from repro.configs.registry import apply_approx, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.engine import modes as engine_modes
from repro.models.registry import build_model
from repro.runtime.fault import FailureInjector, StragglerMonitor, run_loop
from repro.train.steps import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--opt-bits", type=int, default=32, choices=[8, 32])
    ap.add_argument("--compress", type=int, default=0, choices=[0, 8])
    ap.add_argument("--approx-mode", default=None, choices=engine_modes.list_modes(),
                    help="deploy the paper technique via a registered engine mode")
    ap.add_argument("--approx-n", type=int, default=8)
    ap.add_argument("--approx-t", type=int, default=None,
                    help="splitting point; default: resolved by the "
                         "engine.config controller for --approx-n "
                         "(balanced-tier budget)")
    ap.add_argument("--quality-tier", default=None,
                    help="accuracy tier (engine.config): per-GEMM-class "
                         "(n, t, mode) resolved against the tier's error "
                         "budgets; mutually exclusive with --approx-mode")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--inject-failures", default="",
                    help="comma-separated steps at which to raise (fault-tolerance demo)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out", default=None, help="write metrics history JSON here")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.approx_mode and args.quality_tier:
        ap.error("--approx-mode and --quality-tier are mutually exclusive "
                 "(the tier owns the mode)")
    if args.approx_mode:
        cfg = apply_approx(cfg, n=args.approx_n, t=args.approx_t, mode=args.approx_mode)
    elif args.quality_tier:
        from repro.configs.registry import apply_quality

        cfg = apply_quality(cfg, args.quality_tier, n=args.approx_n)
    cfg = dataclasses.replace(cfg, scan_layers=True)

    tcfg = TrainConfig(
        learning_rate=args.lr,
        total_steps=args.steps,
        warmup_steps=max(10, args.steps // 20),
        grad_accum=args.grad_accum,
        opt_state_bits=args.opt_bits,
        grad_compress_bits=args.compress,
        seed=args.seed,
    )
    model = build_model(cfg)
    state = init_train_state(model, tcfg, jax.random.PRNGKey(args.seed))
    n_params = model.param_count(state.params)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M devices={len(jax.devices())}")

    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
    ))

    def batch_fn(step: int) -> dict:
        b = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        if cfg.is_encdec:
            bsz = b["tokens"].shape[0]
            src = jax.random.normal(
                jax.random.fold_in(jax.random.PRNGKey(args.seed + 1), step),
                (bsz, args.seq, cfg.d_model), jnp.float32,
            )
            b["src_embeds"] = src
        return b

    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=0)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    injector = None
    if args.inject_failures:
        injector = FailureInjector(tuple(int(s) for s in args.inject_failures.split(",")))

    result = run_loop(
        state, step_fn, batch_fn,
        total_steps=args.steps,
        ckpt=ckpt,
        checkpoint_every=args.ckpt_every if ckpt else 0,
        injector=injector,
        monitor=StragglerMonitor(),
        log_every=args.log_every,
    )
    first = np.mean([h["loss"] for h in result.metrics_history[:10]])
    last = np.mean([h["loss"] for h in result.metrics_history[-10:]])
    print(f"loss {first:.4f} -> {last:.4f}  failures={result.failures} "
          f"restarts={result.restarts} stragglers={len(result.slow_steps)}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result.metrics_history, f)


if __name__ == "__main__":
    main()
