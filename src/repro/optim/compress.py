"""Int8 error-feedback gradient compression.

At 1000-node scale the cross-pod (DCN) gradient all-reduce is the
bandwidth-critical collective; compressing the pod-boundary traffic 4×
(f32→int8) with an error-feedback residual keeps convergence unbiased
(the quantization error is replayed into the next step's gradient).

``compress``/``decompress`` are pure and jit-safe.  In the train step the
pair wraps the gradient *before* the optimizer; the residual rides in the
train state.  On a real mesh the compressed codes are what crosses the
"pod" axis (psum of int32-accumulated codes); on CPU the semantics are
identical, so tests validate convergence + the residual invariant.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CompressState", "init_state", "compress_grads"]


class CompressState(NamedTuple):
    residual: Any  # pytree of f32, same structure as grads


def init_state(params) -> CompressState:
    return CompressState(
        residual=jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def _q(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0
    code = jnp.round(x / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return code, scale


def compress_grads(grads, state: CompressState) -> tuple[Any, CompressState, dict]:
    """Returns (decompressed grads as would arrive post-allreduce, new state,
    metrics).  Error feedback: e' = (g + e) - dq(q(g + e))."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        code, scale = _q(x)
        deq = code.astype(jnp.float32) * scale
        return deq, x - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(state.residual)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    res = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    err = sum(jnp.sum(jnp.square(r)) for r in jax.tree_util.tree_leaves(res))
    return deq, CompressState(res), {"compress_residual_sq": err}
