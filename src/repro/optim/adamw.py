"""AdamW with optional 8-bit block-quantized moments, cosine schedule,
global-norm clipping.

The 8-bit path stores both Adam moments as (int8 codes, per-block f32
absmax scales) with block size 256 over the flattened tensor — the
standard memory optimization for 1000-node runs where optimizer state
(2×f32) otherwise doubles the parameter memory.  Dequantize→update→
requantize happens inside the (jitted, donated) update, so the f32
moments are never live outside one step.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

__all__ = ["OptState", "init", "update", "schedule", "global_norm"]

_BLOCK = 256


class _Q8(NamedTuple):
    code: jax.Array  # int8
    scale: jax.Array  # f32 (nblocks,)


def _q8(x: jax.Array) -> _Q8:
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    code = jnp.round(blocks / jnp.maximum(scale, 1e-12)[:, None]).astype(jnp.int8)
    return _Q8(code, scale)


def _dq8(q: _Q8, shape) -> jax.Array:
    flat = (q.code.astype(jnp.float32) * q.scale[:, None]).reshape(-1)
    size = 1
    for d in shape:
        size *= d
    return flat[:size].reshape(shape)


class OptState(NamedTuple):
    step: jax.Array
    mu: Any  # pytree of f32 or _Q8
    nu: Any


def schedule(tcfg: TrainConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - tcfg.warmup_steps) / jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return tcfg.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(sq)


def init(params, tcfg: TrainConfig) -> OptState:
    def zeros_like_state(p):
        if tcfg.opt_state_bits == 8:
            return _q8(jnp.zeros_like(p, jnp.float32))
        return jnp.zeros_like(p, jnp.float32)

    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros_like_state, params),
        nu=jax.tree_util.tree_map(zeros_like_state, params),
    )


def update(grads, opt_state: OptState, params, tcfg: TrainConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state.step + 1
    lr = schedule(tcfg, step.astype(jnp.float32))
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-9)) if tcfg.grad_clip else 1.0

    b1, b2 = tcfg.b1, tcfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    q8 = tcfg.opt_state_bits == 8

    is_leaf = (lambda x: isinstance(x, _Q8)) if q8 else None

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        m = _dq8(mu, g.shape) if q8 else mu
        v = _dq8(nu, g.shape) if q8 else nu
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        step_dir = mh / (jnp.sqrt(vh) + 1e-8)
        decay = tcfg.weight_decay if p.ndim >= 2 else 0.0  # no decay on norms/bias
        newp = p.astype(jnp.float32) - lr * (step_dir + decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), (_q8(m) if q8 else m), (_q8(v) if q8 else v)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = jax.tree_util.tree_leaves(opt_state.mu, is_leaf=is_leaf)
    flat_nu = jax.tree_util.tree_leaves(opt_state.nu, is_leaf=is_leaf)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, OptState(step, new_mu, new_nu), metrics
