"""The single dispatch layer for approximate multiplication.

``matmul`` is the one framework-facing approximate GEMM: it quantizes,
looks the mode up in the registry (`repro.engine.modes`), picks a backend
(``reference`` jnp or ``pallas``, with interpret/native auto-selection via
the shared `repro.engine.policy`), and applies the engine-level
straight-through gradient rule to non-differentiable modes so every mode
is trainable without call sites re-implementing gradient hygiene.

``multiply`` is the elementwise counterpart on uint32 magnitudes.

Backends
--------
``reference``  pure-jnp bodies (compile everywhere; the oracle).
``pallas``     tiled VMEM-resident kernels (native on TPU, interpret mode
               elsewhere per ``policy.use_interpret``).  Explicitly
               requesting ``pallas`` for a mode with no Pallas body is a
               ``ValueError`` — no silent reference fallback.
``auto``       ``pallas`` when a Pallas body exists and the policy says
               native lowering is available, else ``reference`` (the one
               documented fallback).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import seqmul as _seqmul
from repro.engine import modes as _modes
from repro.engine.policy import use_interpret

__all__ = ["BACKENDS", "matmul", "multiply", "resolve_backend"]

BACKENDS = ("auto", "reference", "pallas")


def _resolve_nt(n, t):
    """Fill unspecified (n, t) from the accuracy-configuration subsystem:
    the bit-width defaults to ``engine.config.DEFAULT_N`` and the split
    to the controller's ``balanced``-tier resolution for that width —
    the historical hardcoded ``n=8, t=4`` as a derived quantity."""
    from repro.engine import config as _config

    if n is None:
        n = _config.DEFAULT_N
    if t is None:
        t = _config.default_t(n)
    return n, t


# Per-mode bit-width ceilings, validated *eagerly* at dispatch time (the
# kernels historically raised only inside their jitted wrappers, i.e. at
# trace time deep in a model stack).  The limits are structural:
#   bitexact  — gathers a (2^n, 2^n) product LUT (4^n entries).
#   seqmul    — assembles 2n-bit products in f32 (exact for 2n <= 24).
#   inject    — packs quantized magnitudes into int16 lanes (|q| < 2^15).
#   fakequant — symmetric int quantization in f32 (exact for n <= 23).
_MODE_MAX_N = {"bitexact": 8, "lowrank": 8, "seqmul": 12, "inject": 15, "fakequant": 23}

PACKED_U32_MAX_2N = 31  # packed single-word product limit (engine.multiply)


def _validate_mode_nt(mode: str, n: int, t: int) -> None:
    """Eager (n, t) validation with the mode named in the error."""
    from repro.engine.recurrence import validate_nt

    try:
        validate_nt(n, t)
    except ValueError as e:
        raise ValueError(f"mode {mode!r}: {e}") from None
    max_n = _MODE_MAX_N.get(mode)
    if max_n is not None and n > max_n:
        raise ValueError(
            f"mode {mode!r} supports bit-widths n <= {max_n}, got n={n} "
            f"(use mode='seqmul' up to n=12; wider operands go through "
            f"kernels.seqmul_kernel.seqmul_pallas_words)"
        )


def _audit_gate(mode: str, n: int, t: int, *, elementwise: bool = False) -> None:
    """Optional dispatch-time certification gate.

    With ``REPRO_STATIC_AUDIT=1`` in the environment, a Pallas launch is
    refused unless the static analyzer (`repro.analysis`) has certified
    the kernel at this exact (mode, n, t) — overflow, gather-bounds and
    VMEM passes all clean.  Off by default: verdicts are audited in CI
    over the full matrix, so the per-call gate is a belt-and-braces
    check for deployments that want it.
    """
    import os

    if os.environ.get("REPRO_STATIC_AUDIT") != "1":
        return
    from repro.analysis import audit as _audit

    _audit.require_certified(mode, n, t, elementwise=elementwise)


def resolve_backend(backend: str, spec: _modes.ModeSpec | None = None) -> str:
    """Map ``auto`` onto a concrete backend; reject unknown names and an
    explicit ``pallas`` request for a mode with no Pallas body (only
    ``auto`` may fall back to the reference body)."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; valid backends: {list(BACKENDS)}")
    if backend == "pallas" and spec is not None and spec.pallas is None:
        raise ValueError(
            f"mode {spec.name!r} has no Pallas body; backend='pallas' was requested "
            f"explicitly (use backend='auto' for the documented reference fallback)"
        )
    if backend != "auto":
        return backend
    has_pallas = spec is None or spec.pallas is not None
    return "pallas" if (has_pallas and not use_interpret()) else "reference"


def _zero_cotangent(e):
    """A zero cotangent matching ``e``'s *tangent* type.

    Inexact primals get a zero of their own dtype; integer/bool primals
    (e.g. an int32 LUT in a mode's ``extra``) have tangent type
    ``float0``, and handing ``custom_vjp`` an int-dtyped zero instead
    crashes under ``jax.grad``.
    """
    if jnp.issubdtype(jnp.result_type(e), jnp.inexact):
        return jnp.zeros_like(e)
    return np.zeros(jnp.shape(e), jax.dtypes.float0)


def _straight_through(impl, p, x, w, extra):
    """Forward ``impl(x, w, p, *extra)``; backward = exact-matmul grads.

    ``extra`` (any dtypes; every leaf receives a zero cotangent of its
    tangent type) is passed explicitly because ``custom_vjp`` cannot
    close over tracers.
    """

    @jax.custom_vjp
    def f(x, w, extra):
        return impl(x, w, p, *extra)

    def fwd(x, w, extra):
        return impl(x, w, p, *extra), (x, w, extra)

    def bwd(res, g):
        x, w, extra = res
        g = g.astype(jnp.float32)
        return (g @ w.T, x.T @ g, jax.tree_util.tree_map(_zero_cotangent, extra))

    f.defvjp(fwd, bwd)
    return f(x, w, extra)


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    n: int | None = None,
    t: int | None = None,
    fix_to_1: bool = True,
    mode: str = "bitexact",
    rank: int = 8,
    key: jax.Array | None = None,
    backend: str = "auto",
) -> jax.Array:
    """Approximate GEMM: x (M, K) @ w (K, N) -> (M, N) f32.

    ``n``/``t`` left ``None`` are resolved by the accuracy-configuration
    controller (``repro.engine.config``): ``n = DEFAULT_N`` and ``t =
    default_t(n)``, the balanced tier's cheapest valid split.

    Raises ``ValueError`` (listing the valid names) for an unknown
    ``mode`` or ``backend``, for an explicit ``backend="pallas"`` on a
    mode with no Pallas body (only ``auto`` falls back to reference),
    and when a stochastic mode is called without a PRNG ``key``.
    """
    n, t = _resolve_nt(n, t)
    spec = _modes.get_mode(mode)
    _validate_mode_nt(mode, n, t)
    resolved = resolve_backend(backend, spec)
    if spec.needs_key and key is None:
        raise ValueError(f"mode {mode!r} needs a PRNG key")
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    from repro.engine import config as _config

    tiles = _config.kernel_tiles(mode, n, t)
    if resolved == "pallas":
        _audit_gate(mode, n, t)
    p = _modes.GemmParams(
        n=n, t=t, fix_to_1=fix_to_1, rank=rank,
        tiles=(tiles.bm, tiles.bn, tiles.bk),
    )
    extra = spec.prepare(x, w, p, key) if spec.prepare is not None else ()
    impl = spec.pallas if resolved == "pallas" else spec.reference
    if spec.differentiable:
        return impl(x, w, p, *extra)
    return _straight_through(impl, p, x, w, tuple(extra))


def multiply(
    a: jax.Array,
    b: jax.Array,
    *,
    n: int | None = None,
    t: int | None = None,
    approx: bool = True,
    fix_to_1: bool = True,
    backend: str = "auto",
) -> jax.Array:
    """Elementwise (approximate) product of uint32 magnitudes, any shape.

    ``n``/``t`` default to the controller's resolution (see ``matmul``).
    Returns the packed 2n-bit product in uint32 (requires 2n <= 31).
    """
    n, t = _resolve_nt(n, t)
    mode_name = "seqmul_approx" if approx else "seqmul_exact"
    _validate_mode_nt(mode_name, n, t)
    if 2 * n > PACKED_U32_MAX_2N:
        raise ValueError(
            f"multiply (mode {mode_name!r}) packs the 2n-bit product into one "
            f"uint32, which requires 2n <= {PACKED_U32_MAX_2N} (got n={n}, "
            f"2n={2 * n}); use kernels.seqmul_kernel.seqmul_pallas_words for "
            f"the two-word (low, high) output at n up to 16"
        )
    resolved = resolve_backend(backend)
    if resolved == "pallas":
        _audit_gate(mode_name, n, t, elementwise=True)
        from repro.kernels.seqmul_kernel import seqmul_pallas

        return seqmul_pallas(a, b, n=n, t=t, approx=approx, fix_to_1=fix_to_1)
    if approx:
        return _seqmul.seq_mul_approx_u32(a, b, n=n, t=t, fix_to_1=fix_to_1)
    return _seqmul.seq_mul_exact_u32(a, b, n=n)
