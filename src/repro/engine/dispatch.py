"""The single dispatch layer for approximate multiplication.

``matmul`` is the one framework-facing approximate GEMM: it quantizes,
looks the mode up in the registry (`repro.engine.modes`), picks a backend
(``reference`` jnp or ``pallas``, with interpret/native auto-selection via
the shared `repro.engine.policy`), and applies the engine-level
straight-through gradient rule to non-differentiable modes so every mode
is trainable without call sites re-implementing gradient hygiene.

``multiply`` is the elementwise counterpart on uint32 magnitudes.

Backends
--------
``reference``  pure-jnp bodies (compile everywhere; the oracle).
``pallas``     tiled VMEM-resident kernels (native on TPU, interpret mode
               elsewhere per ``policy.use_interpret``).  Modes without a
               Pallas body fall back to their reference body.
``auto``       ``pallas`` when a Pallas body exists and the policy says
               native lowering is available, else ``reference``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import seqmul as _seqmul
from repro.engine import modes as _modes
from repro.engine.policy import use_interpret

__all__ = ["BACKENDS", "matmul", "multiply", "resolve_backend"]

BACKENDS = ("auto", "reference", "pallas")


def resolve_backend(backend: str, spec: _modes.ModeSpec | None = None) -> str:
    """Map ``auto`` onto a concrete backend; reject unknown names."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; valid backends: {list(BACKENDS)}")
    if backend != "auto":
        return backend
    has_pallas = spec is None or spec.pallas is not None
    return "pallas" if (has_pallas and not use_interpret()) else "reference"


def _straight_through(impl, p, x, w, extra):
    """Forward ``impl(x, w, p, *extra)``; backward = exact-matmul grads.

    ``extra`` must be f32 arrays (they receive zero cotangents) and is
    passed explicitly because ``custom_vjp`` cannot close over tracers.
    """

    @jax.custom_vjp
    def f(x, w, extra):
        return impl(x, w, p, *extra)

    def fwd(x, w, extra):
        return impl(x, w, p, *extra), (x, w, extra)

    def bwd(res, g):
        x, w, extra = res
        return (g @ w.T, x.T @ g, jax.tree_util.tree_map(jnp.zeros_like, extra))

    f.defvjp(fwd, bwd)
    return f(x, w, extra)


def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    n: int = 8,
    t: int = 4,
    fix_to_1: bool = True,
    mode: str = "bitexact",
    rank: int = 8,
    key: jax.Array | None = None,
    backend: str = "auto",
) -> jax.Array:
    """Approximate GEMM: x (M, K) @ w (K, N) -> (M, N) f32.

    Raises ``ValueError`` (listing the valid names) for an unknown
    ``mode`` or ``backend``, and when a stochastic mode is called
    without a PRNG ``key``.
    """
    spec = _modes.get_mode(mode)
    resolved = resolve_backend(backend, spec)
    if spec.needs_key and key is None:
        raise ValueError(f"mode {mode!r} needs a PRNG key")
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    p = _modes.GemmParams(n=n, t=t, fix_to_1=fix_to_1, rank=rank)
    extra = spec.prepare(x, w, p, key) if spec.prepare is not None else ()
    impl = spec.pallas if (resolved == "pallas" and spec.pallas is not None) else spec.reference
    if spec.differentiable:
        return impl(x, w, p, *extra)
    return _straight_through(impl, p, x, w, tuple(extra))


def multiply(
    a: jax.Array,
    b: jax.Array,
    *,
    n: int = 8,
    t: int = 4,
    approx: bool = True,
    fix_to_1: bool = True,
    backend: str = "auto",
) -> jax.Array:
    """Elementwise (approximate) product of uint32 magnitudes, any shape.

    Returns the packed 2n-bit product in uint32 (requires 2n <= 31).
    """
    resolved = resolve_backend(backend)
    if resolved == "pallas":
        from repro.kernels.seqmul_kernel import seqmul_pallas

        return seqmul_pallas(a, b, n=n, t=t, approx=approx, fix_to_1=fix_to_1)
    if approx:
        return _seqmul.seq_mul_approx_u32(a, b, n=n, t=t, fix_to_1=fix_to_1)
    return _seqmul.seq_mul_exact_u32(a, b, n=n)
