"""One device-side artifact cache for the whole approximate-multiply stack.

Product LUTs, error LUTs, SVD error factors and error moments used to be
cached independently by ``core.approx_matmul``, ``kernels.ops`` and
``models.layers``; this module is now the single owner.  Everything is
``lru_cache``d per (n, t, ...) configuration, and device conversion runs
under ``jax.ensure_compile_time_eval`` so the caches hold *concrete*
arrays even when first populated inside a jit/scan trace (e.g. an
ApproxDense inside a scanned layer group).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import luts

__all__ = [
    "product_lut",
    "product_lut_flat",
    "error_lut",
    "svd_factors",
    "error_moments",
]


@functools.lru_cache(maxsize=16)
def product_lut(n: int, t: int, fix_to_1: bool = True) -> jax.Array:
    """(2^n, 2^n) int32 approximate-product table, on device."""
    with jax.ensure_compile_time_eval():
        return jnp.asarray(luts.product_lut(n, t, fix_to_1=fix_to_1))


@functools.lru_cache(maxsize=16)
def product_lut_flat(n: int, t: int, fix_to_1: bool = True) -> jax.Array:
    """(2^{2n},) flattened product table (the Pallas LUT kernel's layout)."""
    with jax.ensure_compile_time_eval():
        return product_lut(n, t, fix_to_1).reshape(-1)


@functools.lru_cache(maxsize=16)
def error_lut(n: int, t: int, fix_to_1: bool = True) -> jax.Array:
    """(2^n, 2^n) int32 signed error table (approx - exact), on device."""
    with jax.ensure_compile_time_eval():
        return jnp.asarray(luts.error_lut(n, t, fix_to_1=fix_to_1))


@functools.lru_cache(maxsize=16)
def svd_factors(n: int, t: int, rank: int, fix_to_1: bool = True):
    """Rank-``rank`` SVD factors (u, v, energy) of the error table, on device."""
    u, v, energy = luts.svd_error_factors(n, t, rank, fix_to_1=fix_to_1)
    with jax.ensure_compile_time_eval():
        return jnp.asarray(u), jnp.asarray(v), energy


@functools.lru_cache(maxsize=32)
def error_moments(
    n: int, t: int, fix_to_1: bool = True, dist: str = "gaussian"
) -> tuple[float, float]:
    """(mean, std) of the signed error table under an operand distribution.

    ``dist="uniform"`` is the paper's Fig. 2 setting.  ``dist="gaussian"``
    weights the table by the magnitude PDF of absmax-quantized Gaussian
    activations (|x| ~ folded normal, absmax ≈ 4σ): real activations
    concentrate at small magnitudes where carries rarely cross the split,
    so uniform moments overestimate the injected error by ~an order of
    magnitude (measured in benchmarks/gemm_modes.py).
    """
    e = luts.error_lut(n, t, fix_to_1=fix_to_1).astype(np.float64)
    if dist == "uniform":
        mean, var = float(e.mean()), float(e.var())
    elif dist == "gaussian":
        mags = np.arange(1 << n, dtype=np.float64)
        sigma = (2**n - 1) / 4.0  # absmax calibration: max |x| ~ 4 sigma
        p = np.exp(-0.5 * (mags / sigma) ** 2)
        p /= p.sum()
        w = np.outer(p, p)
        mean = float((w * e).sum())
        var = float((w * e * e).sum()) - mean * mean
    else:
        raise ValueError(f"dist must be 'uniform' or 'gaussian', got {dist!r}")
    # signed sign-magnitude operands: the error rides sign_a*sign_b, whose
    # expectation is 0 for symmetric activations/weights — the *signed*
    # per-product error has zero mean and second moment mean^2 + var
    # (validated empirically in benchmarks/gemm_modes.py).
    return 0.0, float(np.sqrt(max(var + mean * mean, 0.0)))
