"""Backend-dispatching engine for the approximate-multiply stack.

The one home of (a) the execution-mode registry (`repro.engine.modes`),
(b) the reference/pallas backend abstraction with the shared
interpret/native policy (`repro.engine.policy`), (c) the device-side
artifact cache for product/error LUTs and SVD factors
(`repro.engine.artifacts`), and (d) the split-word multiplier recurrence
shared by the jnp reference and the Pallas kernel
(`repro.engine.recurrence`).

Public API (see README §Engine)::

    from repro import engine
    y = engine.matmul(x, w, n=8, t=4, mode="bitexact")   # (M,K)@(K,N) f32
    p = engine.multiply(a, b, n=8, t=4)                  # elementwise u32
    engine.list_modes()       # ['bitexact', 'exact', 'fakequant', ...]
    engine.BACKENDS           # ('auto', 'reference', 'pallas')

Submodules are imported lazily so that leaf modules (``recurrence``,
``policy``) stay importable from ``repro.core``/``repro.kernels`` without
circular imports.
"""

from __future__ import annotations

import importlib

from repro.engine.policy import resolve_interpret, use_interpret  # noqa: F401 (leaf, safe eager)

_LAZY = {
    "matmul": "dispatch",
    "multiply": "dispatch",
    "BACKENDS": "dispatch",
    "resolve_backend": "dispatch",
    "list_modes": "modes",
    "get_mode": "modes",
    "register_mode": "modes",
    "ModeSpec": "modes",
    "GemmParams": "modes",
    "quantize_operands": "modes",
    "bitexact_gemm_int": "modes",
    "seqmul_gemm_int": "modes",
    "resolve_t": "config",
    "kernel_tiles": "config",
    "KernelTiles": "config",
    "resolve_tier": "config",
    "apply_quality": "config",
    "list_tiers": "config",
    "get_tier": "config",
    "ErrorBudget": "config",
    "QualityTier": "config",
    "QualityError": "config",
    "artifacts": None,
    "config": None,
    "dispatch": None,
    "modes": None,
    "policy": None,
    "recurrence": None,
}

__all__ = ["use_interpret", "resolve_interpret"] + sorted(_LAZY)


def __getattr__(name: str):
    if name in _LAZY:
        target = _LAZY[name]
        if target is None:  # submodule itself
            return importlib.import_module(f"repro.engine.{name}")
        return getattr(importlib.import_module(f"repro.engine.{target}"), name)
    raise AttributeError(f"module 'repro.engine' has no attribute {name!r}")
