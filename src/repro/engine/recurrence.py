"""The paper's split-word accumulate-and-shift recurrence — single source.

The n-cycle sequential multiplication is carried out with the accumulator
*already split* at the splitting point ``t`` into an LSP word (t bits) and
an MSP word (n - t + 1 bits, including the adder carry-out S_n).  Exact
and approximate multipliers are the *same* recurrence, differing only in
whether the LSP carry-out is consumed within the cycle (exact: ripple
across the split) or deferred by one clock through the D flip-flop
(approximate: the paper's segmented carry chain).

This module is the one recurrence body in the tree: the jnp reference
(``core.seqmul``) and the Pallas kernel (``kernels.seqmul_kernel``) both
import it, so bit-exactness between them is structural.  It deliberately
has no repro-internal imports — it must be traceable both at the jax
level and inside a Pallas kernel body.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["MAX_N", "validate_nt", "seqmul_recurrence", "pack_u32"]

MAX_N = 32


def validate_nt(n: int, t: int) -> None:
    """Accept 1 <= n <= MAX_N with 1 <= t <= n-1 — except n=1, where the
    split is degenerate (no MSP to segment; ``1 <= t <= n-1`` is
    unsatisfiable) and t=1 is accepted: the single-cycle product never
    produces an LSP carry, so exact and approximate coincide and the
    result is independent of t."""
    if not (1 <= n <= MAX_N):
        raise ValueError(f"bit-width n={n} out of supported range [1, {MAX_N}]")
    if not (1 <= t <= max(1, n - 1)):
        bound = "t == 1 (degenerate split)" if n == 1 else f"1 <= t <= n-1={n - 1}"
        raise ValueError(f"splitting point t={t} for n={n} must satisfy {bound}")


def seqmul_recurrence(
    a: jax.Array,
    b: jax.Array,
    *,
    n: int,
    t: int,
    approx: bool,
    fix_to_1: bool,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Run the n-cycle recurrence, vectorized elementwise over uint32 words.

    Args:
      a: multiplier, uint32, values in [0, 2**n).
      b: multiplicand, uint32, same shape as ``a``.
      n: operand bit-width.
      t: splitting point (LSP is t bits wide).  For ``approx=False`` the
        result is independent of ``t`` (the split add with an immediate
        carry is an exact add); the parameter is kept so exact/approx
        share this one code path.
      approx: defer the LSP carry-out by one cycle (segmented carry chain).
      fix_to_1: on a final-cycle LSP carry-out, force product bits
        [0, n+t) to 1 (the paper's error-compensation multiplexers).
        Ignored for the exact multiplier.

    Returns:
      ``(lo, s_lsp, s_msp, c_last)`` uint32 words: ``lo`` holds product
      bits [0, n-1), ``s_lsp``/``s_msp`` the final accumulator
      S^{n-1} = product bits [n-1, 2n], and ``c_last`` the LSP carry-out
      of the final accumulation, Ĉ_{t-1}^{n-1} (always 0 when exact).
    """
    validate_nt(n, t)
    m_t = jnp.uint32((1 << t) - 1)
    one = jnp.uint32(1)
    zero = jnp.zeros_like(a)

    def cycle(j, state):
        s_lsp, s_msp, c_ff, lo = state
        b_j = (b >> j.astype(jnp.uint32)) & one
        m = jnp.where(b_j.astype(bool), a, zero)
        # augend = S^{j-1} >> 1 (bit t-1 of the LSP receives bit t = MSP LSB)
        aug_lsp = (s_lsp >> 1) | ((s_msp & one) << (t - 1))
        aug_msp = s_msp >> 1
        lsum = aug_lsp + (m & m_t)  # t+1 bits
        c_out = lsum >> t  # Ĉ_{t-1}^{j}: LSP carry-out of this cycle
        # exact: consume the LSP carry now; approx: consume last cycle's.
        c_in = c_ff if approx else c_out
        msum = aug_msp + (m >> t) + c_in  # n-t+1 bits (incl. S_n)
        lo = lo | ((lsum & one) << j.astype(jnp.uint32))
        return lsum & m_t, msum, c_out, lo

    init = (zero, zero, zero, zero)
    s_lsp, s_msp, c_last, lo = jax.lax.fori_loop(0, n, cycle, init)
    lo = lo & jnp.uint32((1 << (n - 1)) - 1) if n > 1 else jnp.zeros_like(lo)

    if approx and fix_to_1:
        hit = c_last.astype(bool)
        lo = jnp.where(hit, jnp.uint32((1 << (n - 1)) - 1) if n > 1 else jnp.uint32(0), lo)
        s_lsp = jnp.where(hit, m_t, s_lsp)
        s_msp = jnp.where(hit, s_msp | one, s_msp)
    return lo, s_lsp, s_msp, c_last


def pack_u32(lo: jax.Array, s_lsp: jax.Array, s_msp: jax.Array, *, n: int, t: int) -> jax.Array:
    """Pack the split-word product into a single uint32 (valid for 2n <= 31)."""
    return lo + ((s_lsp + (s_msp << t)) << (n - 1))
