"""Shared backend-selection policy for the approximate-multiply engine.

Every Pallas entry point in the repo resolves its ``interpret`` flag
through this single policy instead of hard-coding a default: on TPU the
kernels lower natively, everywhere else (CPU containers, unit tests) they
run in interpret mode.  ``REPRO_FORCE_INTERPRET=1`` forces interpret
anywhere (debugging on TPU); ``REPRO_FORCE_INTERPRET=0`` forces native
lowering (e.g. GPU Triton backends, at your own risk).
"""

from __future__ import annotations

import os

import jax

__all__ = ["use_interpret", "resolve_interpret"]


def use_interpret() -> bool:
    """True when Pallas kernels should run in interpret mode."""
    env = os.environ.get("REPRO_FORCE_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve an explicit override (bool) or the shared policy (None)."""
    return use_interpret() if interpret is None else bool(interpret)
