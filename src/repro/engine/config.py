"""Accuracy-configuration subsystem: tiers, error budgets, and the (n, t)
controller.

The paper's headline property is that the splitting point ``t`` is a
*quality knob*: the segmented carry chain shortens the adder critical
path to ``max(t, n - t)`` full-adder delays (paper Fig. 3) at the price
of a deferred-carry error whose magnitude grows with ``t`` (the deferred
carry re-lands one position high with weight 2^t — Eq. 11's MAE
``2^{n+t-1} - 2^{t+1}`` is *increasing* in t, and so is the closed-form
NMED estimate).  Note the direction: unlike truncation-style approximate
multipliers where a wider exact LSP means *less* error, here a larger
``t`` means *more* error and (up to t = n/2) *less* delay — the
accuracy/latency trade-off the controller below navigates.

This module turns that knob into a first-class runtime decision instead
of the historical hardcoded ``n=8, t=4``:

* :func:`resolve_t` — the controller.  It queries
  ``core.error_model.estimate`` (the closed-form Eqs. 9-11 estimator)
  for every candidate split and returns the **cheapest** valid ``t``:
  minimal cycle delay (the same gate-delay model
  ``benchmarks/latency_model`` plots) among the splits whose error
  bounds meet the :class:`ErrorBudget`, ties broken toward the smaller
  (more accurate) split.  Because the error metrics are monotone in
  ``t`` the valid set is the lower interval ``[1, t_max]``, so for any
  budget binding at or below the delay-optimal split the controller
  returns the *unique* cheapest valid ``t = t_max``.
* :class:`QualityTier` / :func:`resolve_tier` — named tiers (``exact``,
  ``high``, ``balanced``, ``draft``) carrying per-GEMM-class
  (mlp / attn / moe) error budgets; resolution produces one
  :class:`~repro.configs.base.LayerQuality` per class.
* :func:`apply_quality` — deploys a resolved tier onto a
  ``ModelConfig`` (per-target overrides ride in
  ``ApproxConfig.overrides``; ``dense``/``moe`` resolve them per call
  site via ``ApproxConfig.for_target``).
* :func:`default_t` — the engine-wide default split for a bit-width,
  resolved from the ``balanced`` tier's mlp budget.  ``default_t(8) ==
  4``: the old hardcoded default is now a *derived* quantity.

The serving layer consumes the same tiers per request
(``repro.serve``: requests carry a tier name, the scheduler resolves it
to the pool's engine config at admission), and the
``accuracy_pareto`` benchmark suite sweeps the controller's candidate
set and records the measured error-vs-throughput Pareto front.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

from repro.configs.base import ApproxConfig, LayerQuality, ModelConfig
from repro.core import error_model

__all__ = [
    "T_FA",
    "T_MUX",
    "ripple_delay",
    "segmented_delay",
    "cycle_delay",
    "ErrorBudget",
    "TPoint",
    "QualityError",
    "sweep_t",
    "resolve_t",
    "DEFAULT_N",
    "default_t",
    "KernelTiles",
    "kernel_tiles",
    "QualityTier",
    "QualityConfig",
    "register_tier",
    "get_tier",
    "list_tiers",
    "resolve_tier",
    "apply_quality",
    "tier_cycle_factor",
    "accept_rate_estimate",
    "expected_round_tokens",
    "speculation_gain",
    "best_spec_k",
]


# ------------------------------------------------------------- cycle cost
# Normalized gate-delay model of the per-cycle critical path (paper
# Fig. 3); ``benchmarks/latency_model.py`` imports these so the plotted
# trade-off and the controller's objective cannot drift apart.
T_FA = 1.0  # full-adder delay
T_MUX = 0.4  # fix-to-1 mux + D-FF setup margin


def ripple_delay(n: int) -> float:
    """Accurate multiplier: the carry ripples across all n positions."""
    return n * T_FA


def segmented_delay(n: int, t: int) -> float:
    """Approximate multiplier: the D-FF cuts the chain at ``t``; the
    critical path is the longer segment plus the fix-to-1 mux."""
    return max(t, n - t) * T_FA + T_MUX


def cycle_delay(n: int, t: int) -> float:
    """The controller's cost: per-cycle critical path of the (n, t) design."""
    return segmented_delay(n, t)


# ---------------------------------------------------------- error budgets
@dataclasses.dataclass(frozen=True)
class ErrorBudget:
    """Upper bounds a resolved split must satisfy (``None`` = unbounded).

    ``max_er`` bounds the estimator's ``er_msp`` (itself an upper
    estimate of the true error rate — see the calibration tests), so a
    budget met in closed form is met by the hardware.  ``max_nmed``
    bounds the deferred-carry-ledger MED estimate normalized by the
    maximum product ``(2^n - 1)^2`` (strictly increasing in t — the
    quality knob's native scale).  ``max_mae`` bounds Eq. 11.
    """

    max_er: Optional[float] = None
    max_nmed: Optional[float] = None
    max_mae: Optional[int] = None

    def admits(self, point: "TPoint") -> bool:
        if self.max_er is not None and point.er_bound > self.max_er:
            return False
        if self.max_nmed is not None and point.nmed_est > self.max_nmed:
            return False
        if self.max_mae is not None and point.mae > self.max_mae:
            return False
        return True


@dataclasses.dataclass(frozen=True)
class TPoint:
    """One candidate split with its closed-form metrics and cycle cost."""

    n: int
    t: int
    order: int
    er_bound: float  # estimate(...).er_msp — ER upper estimate (Eq. 10)
    med_abs_est: float  # deferred-carry weight-ledger MED estimate
    nmed_est: float  # med_abs_est / (2^n - 1)^2
    mae: int  # Eq. 11 closed form
    delay: float  # cycle_delay(n, t)


class QualityError(ValueError):
    """No splitting point satisfies the requested error budget."""


def _sweep(n: int, order: int, pa, pb) -> tuple:
    points = []
    max_p = max((2**n - 1) ** 2, 1)
    for t in range(1, max(1, n - 1) + 1):
        est = error_model.estimate(n, t, order=order, pa=pa, pb=pb)
        points.append(TPoint(
            n=n,
            t=t,
            order=order,
            er_bound=est.er_msp,
            med_abs_est=est.med_abs_est,
            nmed_est=est.med_abs_est / max_p,
            mae=error_model.mae_closed_form(n, t),
            delay=cycle_delay(n, t),
        ))
    return tuple(points)


@functools.lru_cache(maxsize=256)
def sweep_t(n: int, *, order: int = 1) -> tuple:
    """Closed-form metrics for every valid split of bit-width ``n``.

    Uniform input marginals (the estimator's default); a measured input
    PDF can be folded in by calling :func:`resolve_t` with explicit
    ``pa``/``pb`` instead.
    """
    return _sweep(n, order, None, None)


def resolve_t(
    n: int,
    budget: ErrorBudget,
    *,
    order: int = 1,
    pa=None,
    pb=None,
    mode: Optional[str] = None,
) -> TPoint:
    """The controller: cheapest split meeting ``budget``.

    Enumerates every candidate ``t``, keeps those whose closed-form
    bounds satisfy the budget, and returns the one minimizing
    ``(cycle_delay, t)`` — the cheapest configuration, ties broken
    toward the more accurate (smaller) split.  Since the error metrics
    grow with ``t``, the valid set is ``[1, t_max]``; whenever the
    budget binds at or below the delay-optimal split the result is the
    unique cheapest valid ``t = t_max``.  Raises :class:`QualityError`
    when even ``t = 1`` exceeds the budget.

    With ``mode`` set, candidates are additionally filtered through the
    static kernel audit (:func:`repro.analysis.audit.certified`): the
    controller can only return a (n, t) whose traced kernel the
    analyzer has proven overflow/gather/VMEM-safe, so an uncertified
    configuration is unreachable through tier resolution by
    construction.  Raises :class:`QualityError` naming certification
    when the audit filter empties the budget-valid set.
    """
    if pa is None and pb is None:
        points = sweep_t(n, order=order)
    else:  # measured input marginals: uncached per-call sweep
        points = _sweep(n, order, pa, pb)
    valid = [p for p in points if budget.admits(p)]
    if not valid:
        raise QualityError(
            f"no splitting point t in [1, {max(1, n - 1)}] for n={n} meets "
            f"{budget} (tightest candidate: t=1 with er<={points[0].er_bound:.3f}, "
            f"nmed<={points[0].nmed_est:.2e}, mae={points[0].mae})"
        )
    if mode is not None:
        from repro.analysis import audit  # lazy: analysis imports us

        certified = [p for p in valid if audit.certified(mode, n, p.t)]
        if not certified:
            raise QualityError(
                f"every budget-valid splitting point for mode {mode!r} at "
                f"n={n} (t in {[p.t for p in valid]}) failed static kernel "
                f"certification; run `python -m repro.launch.analyze` for "
                f"the findings"
            )
        valid = certified
    return min(valid, key=lambda p: (p.delay, p.t))


DEFAULT_N = 8  # LUT-backed modes require n <= 8; the engine-wide default


# ------------------------------------------------- fused-kernel parameters
@dataclasses.dataclass(frozen=True)
class KernelTiles:
    """Blocked-kernel tile sizes for one fused GEMM call.

    ``bm``/``bn``/``bk`` are the (M, N, K) block extents of the
    (M/BM, N/BN, K/BK) reduction grid every fused Pallas GEMM in
    ``repro.kernels`` uses.  Resolved per call by :func:`kernel_tiles`
    from the mode and the controller-chosen (n, t) — this is how a
    :class:`~repro.configs.base.LayerQuality` selection turns into
    concrete fused-kernel launch parameters instead of an outer loop
    around generic kernels.
    """

    bm: int
    bn: int
    bk: int


# VMEM sizing (machine-checked: every selection below must pass
# repro.analysis.vmem.validate_tiles — positive, power-of-two, and the
# closed-form footprint under budget; `launch/analyze.py --report`
# emits the traced numbers that docs/kernels.md is generated from):
#  * seqmul keeps ~8 live uint32 (BM, BK, BN) cubes -> cube edge 32
#    (~1 MiB live) fits every n; n <= 4 halves the LUT-free live set so
#    a 64-edge cube (~8 MiB live) still fits and shrinks the grid 8x.
#  * lut pins the (2^n, 2^n) table (256 KiB at n=8) + the (BM, BK, BN)
#    gather cube -> 64 tiles (~6 MiB live worst case).
#  * lowrank/packed are pure MXU dot kernels -> 128 tiles.
_SEQMUL_TILES_SMALL_N = KernelTiles(bm=64, bn=64, bk=64)
_SEQMUL_TILES = KernelTiles(bm=32, bn=32, bk=32)
_LUT_TILES = KernelTiles(bm=64, bn=64, bk=64)
_MXU_TILES = KernelTiles(bm=128, bn=128, bk=128)


@functools.lru_cache(maxsize=1024)
def kernel_tiles(mode: str, n: int, t: int) -> KernelTiles:
    """Fused-kernel tile selection for a (mode, n, t) GEMM call.

    The splitting point ``t`` does not change the VMEM footprint (both
    split words live regardless of where the cut sits), so tiles depend
    on the mode's live-set shape and the bit-width; ``t`` itself enters
    the kernel *body* (the in-tile recurrence / the LUT contents).

    Every selection is validated eagerly against the static VMEM model
    (:func:`repro.analysis.vmem.validate_tiles`): a non-positive or
    non-power-of-two extent, or a footprint over the 16 MiB budget,
    raises :class:`~repro.analysis.vmem.TileBudgetError` naming the
    (mode, n, t) — at resolution time, not inside Pallas lowering.
    """
    if mode == "seqmul":
        tiles = _SEQMUL_TILES_SMALL_N if n <= 4 else _SEQMUL_TILES
    elif mode == "bitexact":
        tiles = _LUT_TILES
    else:
        tiles = _MXU_TILES
    from repro.analysis.vmem import validate_tiles  # lazy: analysis imports us

    validate_tiles(mode, n, t, (tiles.bm, tiles.bn, tiles.bk))
    return tiles


@functools.lru_cache(maxsize=64)
def default_t(n: int = DEFAULT_N) -> int:
    """Engine-wide default split for bit-width ``n``: the ``balanced``
    tier's mlp budget resolved by the controller.  ``default_t(8) == 4``
    — the historical hardcoded default, now derived."""
    tier = get_tier("balanced")
    return resolve_t(n, dict(tier.budgets)["mlp"]).t


# ----------------------------------------------------------------- tiers
@dataclasses.dataclass(frozen=True)
class QualityTier:
    """A named quality level: an engine mode plus per-GEMM-class budgets.

    ``budgets`` maps targets (``mlp`` / ``attn`` / ``moe``) to
    :class:`ErrorBudget`; a target without a budget stays exact.  The
    ``exact`` tier has no budgets at all — approximation disabled.
    """

    name: str
    mode: str  # engine mode deployed at this tier ("exact" disables)
    budgets: tuple = ()  # ((target, ErrorBudget), ...)
    backend: str = "auto"
    description: str = ""

    @property
    def targets(self) -> tuple:
        return tuple(t for t, _ in self.budgets)


@dataclasses.dataclass(frozen=True)
class QualityConfig:
    """A tier resolved against a bit-width: one LayerQuality per target."""

    tier: str
    n: int
    order: int
    mode: str
    backend: str
    per_target: tuple  # of LayerQuality

    @property
    def targets(self) -> tuple:
        return tuple(q.target for q in self.per_target)

    def describe(self) -> str:
        if not self.per_target:
            return f"tier {self.tier}: exact (approximation disabled)"
        cells = ", ".join(
            f"{q.target}(n={q.n}, t={q.t}, {q.mode or self.mode})"
            for q in self.per_target
        )
        return f"tier {self.tier}: {cells} [{self.backend}]"


_TIERS: dict[str, QualityTier] = {}


def register_tier(tier: QualityTier) -> QualityTier:
    if tier.name in _TIERS:
        raise ValueError(f"tier {tier.name!r} is already registered")
    _TIERS[tier.name] = tier
    return tier


def get_tier(name: Union[str, QualityTier]) -> QualityTier:
    if isinstance(name, QualityTier):
        return name
    try:
        return _TIERS[name]
    except KeyError:
        raise ValueError(
            f"unknown quality tier {name!r}; registered tiers: {list_tiers()}"
        ) from None


def list_tiers() -> list[str]:
    return sorted(_TIERS)


# Budgets are on the NMED scale (strictly increasing in t, so each budget
# selects a unique t_max per bit-width).  At the default n=8 these
# resolve to: high -> mlp/moe t=2, attn t=1; balanced -> mlp/moe t=4
# (the old hardcoded default), attn t=2; draft -> delay-optimal t=4 with
# the O(1) inject surrogate.  The resolutions are pinned by tests.
register_tier(QualityTier(
    name="exact",
    mode="exact",
    description="no approximation (baseline quality)",
))
register_tier(QualityTier(
    name="high",
    mode="bitexact",
    budgets=(
        ("mlp", ErrorBudget(max_nmed=2e-3)),
        ("moe", ErrorBudget(max_nmed=2e-3)),
        ("attn", ErrorBudget(max_nmed=1e-3)),
    ),
    description="tight NMED budget; short splits, attention tightest",
))
register_tier(QualityTier(
    name="balanced",
    mode="bitexact",
    budgets=(
        ("mlp", ErrorBudget(max_nmed=1e-2)),
        ("moe", ErrorBudget(max_nmed=1e-2)),
        ("attn", ErrorBudget(max_nmed=2e-3)),
    ),
    description="the paper's working point: delay-optimal mlp split at n=8",
))
register_tier(QualityTier(
    name="draft",
    mode="inject",
    budgets=(
        ("mlp", ErrorBudget(max_nmed=5e-2)),
        ("moe", ErrorBudget(max_nmed=5e-2)),
    ),
    description="loose budget, moment-matched injection (throughput first)",
))


def resolve_tier(
    tier: Union[str, QualityTier],
    *,
    n: int = DEFAULT_N,
    order: int = 1,
) -> QualityConfig:
    """Resolve a tier's budgets into concrete per-target (n, t) selections.

    Each selection passes through :func:`resolve_t` with the tier's mode,
    so every (n, t) a tier hands out is statically certified.
    """
    spec = get_tier(tier)
    per_target = tuple(
        LayerQuality(
            target=target,
            n=n,
            t=resolve_t(n, budget, order=order, mode=spec.mode).t,
            mode=spec.mode,
            backend=spec.backend,
        )
        for target, budget in spec.budgets
    )
    return QualityConfig(
        tier=spec.name, n=n, order=order, mode=spec.mode,
        backend=spec.backend, per_target=per_target,
    )


@functools.lru_cache(maxsize=64)
def tier_cycle_factor(
    tier: Optional[str],
    *,
    n: int = DEFAULT_N,
    order: int = 1,
) -> float:
    """Relative per-cycle cost of serving at ``tier`` vs the exact design.

    The mean segmented critical path over the tier's resolved per-target
    splits, normalized by the accurate multiplier's ripple delay — i.e.
    ``mean(segmented_delay(n, t_target)) / ripple_delay(n)`` with every
    ``t_target`` chosen by :func:`resolve_tier`'s controller.  ``exact``
    (or ``None``) is the ripple design itself: factor 1.0.

    This is the gate-delay model's answer to "how much faster is one
    decode step at this tier", and it is what the serving layer's
    deterministic virtual clock charges per step (``repro.serve``): a
    cheaper tier genuinely shortens virtual step time, so SLO-adaptive
    tier degradation buys real (modeled) throughput.  At n=8 the
    registered tiers come out monotone: exact 1.0 > high > balanced >
    draft — pinned by tests.
    """
    if tier is None:
        return 1.0
    qc = resolve_tier(tier, n=n, order=order)
    if not qc.per_target:  # exact: approximation disabled
        return 1.0
    mean_delay = sum(segmented_delay(q.n, q.t) for q in qc.per_target)
    mean_delay /= len(qc.per_target)
    return mean_delay / ripple_delay(n)


# ------------------------------------------------- self-speculative decoding
@functools.lru_cache(maxsize=256)
def accept_rate_estimate(
    draft_tier: Union[str, QualityTier],
    verify_tier: Union[str, QualityTier],
    *,
    n: int = DEFAULT_N,
    order: int = 1,
) -> float:
    """Closed-form lower bound on the draft-vs-verify agreement rate.

    Self-speculative decoding (``repro.serve.strategy``) runs the *same*
    weights at two tiers; a draft proposal is accepted when both tiers'
    greedy argmax agree.  The tiers differ only through their
    approximate multiplies, so per budgeted GEMM class the probability
    that *either* tier's multiply deviates from exact is union-bounded
    by the sum of the two resolved splits' Eq. 10 ER estimates
    (``sweep_t(n)[t-1].er_bound``); the product over classes of
    ``max(0, 1 - (er_d + er_v))`` lower-bounds the chance that every
    multiply in both forwards agrees with the exact computation — and
    two computations that each match exact match each other.  Argmax
    additionally absorbs deviations too small to reorder the top logit,
    so the *measured* accept rate sits at or above this estimate (the
    ``speculative`` benchmark suite gates exactly that inequality).

    Degenerate pairs resolve to 1.0: two tiers with identical resolved
    (mode, per-target) configurations run bit-identical forwards.
    """
    qd = resolve_tier(get_tier(draft_tier), n=n, order=order)
    qv = resolve_tier(get_tier(verify_tier), n=n, order=order)
    if (qd.mode, qd.per_target) == (qv.mode, qv.per_target):
        return 1.0

    def er(qc: QualityConfig, target: str) -> float:
        for q in qc.per_target:
            if q.target == target:
                return sweep_t(q.n, order=order)[q.t - 1].er_bound
        return 0.0  # unbudgeted target: exact at this tier

    targets = {q.target for q in qd.per_target} | {q.target for q in qv.per_target}
    est = 1.0
    for tgt in sorted(targets):
        est *= max(0.0, 1.0 - (er(qd, tgt) + er(qv, tgt)))
    return est


def expected_round_tokens(accept_rate: float, k: int) -> float:
    """Expected committed tokens of one speculative round at depth ``k``.

    Acceptance is a per-position Bernoulli(α) chain stopped at the first
    rejection, plus the verify step's own "bonus" token, so the round
    commits ``1 + accepted`` tokens with expectation
    ``(1 - α^(k+1)) / (1 - α)`` — the truncated geometric series —
    reaching ``k + 1`` exactly at α = 1.
    """
    if not 0.0 <= accept_rate <= 1.0:
        raise ValueError(f"accept_rate must be in [0, 1], got {accept_rate}")
    if k < 1:
        raise ValueError(f"speculation depth k must be >= 1, got {k}")
    if accept_rate >= 1.0:
        return float(k + 1)
    return (1.0 - accept_rate ** (k + 1)) / (1.0 - accept_rate)


def speculation_gain(
    draft_tier: Union[str, QualityTier],
    verify_tier: Union[str, QualityTier],
    k: int,
    *,
    n: int = DEFAULT_N,
    order: int = 1,
) -> float:
    """Modeled tokens-per-cost ratio of speculating vs plain verify decode.

    One speculative round costs ``k * f_draft + f_verify`` exact-step
    units on the gate-delay clock (:func:`tier_cycle_factor`) and
    commits ``E = expected_round_tokens(α, k)`` verify-quality tokens;
    plain decode buys one token per ``f_verify``.  The gain is
    ``E * f_verify / (k * f_draft + f_verify)`` — above 1.0 speculation
    is worth it, and at ``draft == verify`` it is exactly 1.0 with the
    degenerate α = 1 (the bound and the cost model agree that
    self-speculating against yourself is a no-op).
    """
    alpha = accept_rate_estimate(draft_tier, verify_tier, n=n, order=order)
    e_tokens = expected_round_tokens(alpha, k)
    f_d = tier_cycle_factor(get_tier(draft_tier).name, n=n, order=order)
    f_v = tier_cycle_factor(get_tier(verify_tier).name, n=n, order=order)
    return e_tokens * f_v / (k * f_d + f_v)


def best_spec_k(
    draft_tier: Union[str, QualityTier],
    verify_tier: Union[str, QualityTier],
    *,
    k_max: int = 8,
    n: int = DEFAULT_N,
    order: int = 1,
) -> tuple[int, float]:
    """The controller's pick of speculation depth: ``(k, gain)`` maximizing
    :func:`speculation_gain` over ``1 <= k <= k_max`` (ties toward the
    smaller, lower-variance depth).  Callers treat ``gain <= 1`` as
    "don't speculate"."""
    if k_max < 1:
        raise ValueError(f"k_max must be >= 1, got {k_max}")
    best = (1, speculation_gain(draft_tier, verify_tier, 1, n=n, order=order))
    for k in range(2, k_max + 1):
        g = speculation_gain(draft_tier, verify_tier, k, n=n, order=order)
        if g > best[1]:
            best = (k, g)
    return best


def apply_quality(
    cfg: ModelConfig,
    tier: Union[str, QualityTier],
    *,
    n: int = DEFAULT_N,
    order: int = 1,
) -> ModelConfig:
    """Deploy a quality tier onto a model config.

    The ``exact`` tier (no budgets) disables approximation outright.
    Otherwise every budgeted target gets its controller-resolved
    :class:`LayerQuality` as an ``ApproxConfig`` override, so the dense /
    attention / MoE call sites each run their own (n, t, mode, backend)
    — the per-layer(-class) selection the paper's accuracy
    configurability promises.
    """
    qc = resolve_tier(tier, n=n, order=order)
    if not qc.per_target:
        return dataclasses.replace(cfg, approx=ApproxConfig(enabled=False))
    from repro.engine import modes as engine_modes  # lazy: avoid heavy import

    engine_modes.get_mode(qc.mode)
    base = qc.per_target[0]
    return dataclasses.replace(cfg, approx=ApproxConfig(
        enabled=True,
        n=base.n,
        t=base.t,
        fix_to_1=cfg.approx.fix_to_1,
        mode=qc.mode,
        rank=cfg.approx.rank,
        targets=qc.targets,
        backend=qc.backend,
        overrides=qc.per_target,
    ))
