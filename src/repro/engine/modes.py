"""Mode registry for the approximate-GEMM engine.

Every execution mode of the paper's accuracy-configurable multiplier is a
registered :class:`ModeSpec` carrying its reference (pure-jnp) body, its
optional Pallas body, and its gradient/PRNG requirements.  Consumers
never branch on mode strings: ``repro.engine.matmul`` looks the mode up
here and dispatches; an unknown name raises with the list of valid names.

Built-in modes
--------------
``exact``      plain f32 matmul (the baseline the paper compares against).
``bitexact``   every scalar product is the paper's approximate multiplier,
               via the (2^n, 2^n) product LUT (n <= 8): faithful
               semantics; gather-bound on the VPU, LUT kernel on TPU.
``seqmul``     the split-word recurrence itself fused into the blocked
               GEMM tile loop (`kernels.seqmul_matmul`): no LUT, so any
               n <= 12 — the path that runs the paper's 16-bit-family
               configurations the (2^n)^2 tables cannot reach.
``lowrank``    exact matmul + rank-r SVD correction of the error table —
               both terms run on the MXU.  Beyond-paper optimization.
``inject``     exact matmul + moment-matched Gaussian error injection
               (mean/var calibrated from the error table, scaled by √K):
               O(1) overhead surrogate for 1000-node approximate-aware
               training.
``fakequant``  straight-through fake quantization of both operands (QAT
               substrate; no multiplier error model).

Third parties can ``register_mode`` additional entries; the engine's
straight-through gradient rule applies automatically to any mode with
``differentiable=False``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quantization
from repro.engine import artifacts, recurrence

__all__ = [
    "GemmParams",
    "ModeSpec",
    "register_mode",
    "get_mode",
    "list_modes",
    "resolve_key",
    "quantize_operands",
    "bitexact_gemm_int",
    "seqmul_gemm_int",
]


class GemmParams(NamedTuple):
    """Static configuration threaded to every mode body.

    ``tiles`` is the fused-kernel (bm, bn, bk) block selection resolved
    by ``engine.config.kernel_tiles`` at dispatch time (``None`` lets
    each kernel use its module default) — the hook through which a
    quality tier's ``LayerQuality`` becomes concrete launch parameters.
    """

    n: int
    t: int
    fix_to_1: bool
    rank: int
    tiles: Optional[tuple] = None


@dataclasses.dataclass(frozen=True)
class ModeSpec:
    """One registered execution mode.

    ``reference``/``pallas`` have signature ``(x, w, p, *extra) -> out``
    on f32 2-D operands; ``extra`` is whatever ``prepare`` returned (f32
    arrays only — they receive zero cotangents under the straight-through
    rule).  ``pallas=None`` means the reference body runs on every
    backend.  ``differentiable=False`` makes the engine wrap the forward
    in a straight-through ``custom_vjp`` whose backward is the exact
    matmul gradient, so the mode is trainable without call sites
    re-implementing gradient hygiene.

    ``exact_products`` declares the mode's *static* parity contract:
    integer-valued f32 products must stay under the exactly-
    representable 2^24 before any reduction.  The jaxpr auditor
    (`repro.analysis`) enforces it as a gated pass for modes that set
    it; float-valued modes (lowrank's SVD correction, fakequant) and
    modes whose integer bounds the interval domain cannot see (inject's
    bit-packed lanes — parity asserted dynamically in tests) leave it
    False.
    """

    name: str
    reference: Callable
    pallas: Optional[Callable] = None
    prepare: Optional[Callable] = None  # (x, w, p, key) -> tuple of f32 arrays
    needs_key: bool = False
    differentiable: bool = True
    exact_products: bool = False
    description: str = ""


_REGISTRY: dict[str, ModeSpec] = {}


def register_mode(spec: ModeSpec) -> ModeSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"mode {spec.name!r} is already registered")
    _REGISTRY[spec.name] = spec
    return spec


def get_mode(name: str) -> ModeSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown mode {name!r}; registered modes: {list_modes()}"
        ) from None


def list_modes() -> list[str]:
    return sorted(_REGISTRY)


def resolve_key(mode: str, key):
    """The PRNG key a model layer should hand to ``matmul`` for ``mode``.

    Stochastic modes with no key fall back to a fixed default key — the
    deterministic-eval behavior shared by the dense and MoE layers.
    (``matmul`` itself stays strict and raises without a key, so direct
    engine callers can't silently reuse noise.)
    """
    if get_mode(mode).needs_key and key is None:
        return jax.random.PRNGKey(0)
    return key


# ------------------------------------------------------------------ helpers
def quantize_operands(x: jax.Array, w: jax.Array, n: int):
    """Sign-magnitude absmax quantization of both GEMM operands.

    Returns ``((mag_x, sign_x), (mag_w, sign_w), scale)`` with the
    calibration stop-gradiented (scales are data, not parameters).
    """
    qx = quantization.calibrate_absmax(jax.lax.stop_gradient(x), bits=n)
    qw = quantization.calibrate_absmax(jax.lax.stop_gradient(w), bits=n)
    mx, sx = quantization.quantize(x, qx)
    mw, sw = quantization.quantize(w, qw)
    return (mx, sx), (mw, sw), qx.scale * qw.scale


def bitexact_gemm_int(
    mag_a: jax.Array,
    sign_a: jax.Array,
    mag_b: jax.Array,
    sign_b: jax.Array,
    *,
    n: int,
    t: int,
    fix_to_1: bool = True,
) -> jax.Array:
    """Bit-exact signed approximate GEMM on integer sign-magnitude operands.

    mag_a (M, K) uint32, mag_b (K, N) uint32, signs int8.  Returns f32
    (M, N) — accumulations are float32, exact for n <= 8 and K <= 2^8
    (|sum| < 2^24); asserted in tests.
    """
    lut = artifacts.product_lut_flat(n, t, fix_to_1)
    idx = mag_a[:, :, None] * jnp.uint32(1 << n) + mag_b[None, :, :]
    prod = jnp.take(lut, idx.astype(jnp.int32), axis=0)  # (M, K, N)
    signed = prod.astype(jnp.float32) * (
        sign_a.astype(jnp.float32)[:, :, None] * sign_b.astype(jnp.float32)[None, :, :]
    )
    return signed.sum(axis=1)


# ------------------------------------------------------------ mode bodies
def _exact_ref(x, w, p):
    return x @ w


def _bitexact_ref(x, w, p):
    (mx, sx), (mw, sw), scale = quantize_operands(x, w, p.n)
    acc = bitexact_gemm_int(mx, sx, mw, sw, n=p.n, t=p.t, fix_to_1=p.fix_to_1)
    return acc * scale


def _tile_kw(p):
    """Fused-kernel launch overrides from the dispatch-resolved tiles."""
    if p.tiles is None:
        return {}
    bm, bn, bk = p.tiles
    return {"bm": bm, "bn": bn, "bk": bk}


def _bitexact_pallas(x, w, p):
    from repro.kernels.lut_matmul import lut_matmul_pallas

    (mx, sx), (mw, sw), scale = quantize_operands(x, w, p.n)
    out = lut_matmul_pallas(
        artifacts.product_lut_flat(p.n, p.t, p.fix_to_1),
        mx,
        sx.astype(jnp.float32),
        mw,
        sw.astype(jnp.float32),
        n=p.n,
        **_tile_kw(p),
    )
    return out * scale


# ---- seqmul: the recurrence itself as a blocked GEMM (no LUT, any n <= 12)
def seqmul_gemm_int(
    mag_a: jax.Array,
    sign_a: jax.Array,
    mag_b: jax.Array,
    sign_b: jax.Array,
    *,
    n: int,
    t: int,
    approx: bool = True,
    fix_to_1: bool = True,
) -> jax.Array:
    """Reference oracle for the fused seqmul GEMM: run the split-word
    recurrence on the full (M, K, N) outer-product cube in jnp and
    reduce.  O(M·K·N) intermediate — the flatten-everything layout the
    fused kernel exists to avoid; kept as the bit-exact oracle."""
    m_dim, k_dim = mag_a.shape
    n_dim = mag_b.shape[1]
    a3 = jnp.broadcast_to(jnp.asarray(mag_a, jnp.uint32)[:, :, None], (m_dim, k_dim, n_dim))
    b3 = jnp.broadcast_to(jnp.asarray(mag_b, jnp.uint32)[None, :, :], (m_dim, k_dim, n_dim))
    lo, s_lsp, s_msp, _ = recurrence.seqmul_recurrence(
        a3, b3, n=n, t=t, approx=approx, fix_to_1=fix_to_1
    )
    prod = lo.astype(jnp.float32) + jnp.float32(1 << (n - 1)) * (
        s_lsp.astype(jnp.float32) + jnp.float32(1 << t) * s_msp.astype(jnp.float32)
    )
    signed = prod * (
        sign_a.astype(jnp.float32)[:, :, None] * sign_b.astype(jnp.float32)[None, :, :]
    )
    return signed.sum(axis=1)


def _seqmul_ref(x, w, p):
    (mx, sx), (mw, sw), scale = quantize_operands(x, w, p.n)
    acc = seqmul_gemm_int(mx, sx, mw, sw, n=p.n, t=p.t, fix_to_1=p.fix_to_1)
    return acc * scale


def _seqmul_pallas(x, w, p):
    from repro.kernels.seqmul_matmul import seqmul_matmul_pallas

    (mx, sx), (mw, sw), scale = quantize_operands(x, w, p.n)
    out = seqmul_matmul_pallas(
        mx,
        sx.astype(jnp.float32),
        mw,
        sw.astype(jnp.float32),
        n=p.n,
        t=p.t,
        fix_to_1=p.fix_to_1,
        **_tile_kw(p),
    )
    return out * scale


def _lowrank_embed(mx, sx, mw, sw, p):
    u, v, _ = artifacts.svd_factors(p.n, p.t, p.rank, p.fix_to_1)
    ue = u[mx.astype(jnp.int32)] * sx.astype(jnp.float32)[..., None]  # (M, K, r)
    ve = v[mw.astype(jnp.int32)] * sw.astype(jnp.float32)[..., None]  # (K, N, r)
    return ue, ve


def _lowrank_ref(x, w, p):
    (mx, sx), (mw, sw), scale = quantize_operands(x, w, p.n)
    ax = mx.astype(jnp.float32) * sx.astype(jnp.float32)
    aw = mw.astype(jnp.float32) * sw.astype(jnp.float32)
    ue, ve = _lowrank_embed(mx, sx, mw, sw, p)
    corr = jnp.einsum("ikr,kjr->ij", ue, ve)
    return (ax @ aw + corr) * scale


def _lowrank_pallas(x, w, p):
    from repro.kernels.lowrank_matmul import lowrank_matmul_pallas

    (mx, sx), (mw, sw), scale = quantize_operands(x, w, p.n)
    ax = mx.astype(jnp.float32) * sx.astype(jnp.float32)
    aw = mw.astype(jnp.float32) * sw.astype(jnp.float32)
    ue, ve = _lowrank_embed(mx, sx, mw, sw, p)
    out = lowrank_matmul_pallas(ax, aw, ue, ve, rank=p.rank, **_tile_kw(p))
    return out * scale


def _inject_prepare(x, w, p, key):
    """Pre-draw the moment-matched noise (shape is static: (M, N))."""
    mean, std = artifacts.error_moments(p.n, p.t, p.fix_to_1)
    k_dim = x.shape[-1]
    noise = mean * k_dim + std * jnp.sqrt(jnp.float32(k_dim)) * jax.random.normal(
        key, (x.shape[0], w.shape[-1]), jnp.float32
    )
    return (noise,)


def _inject_ref(x, w, p, noise):
    (mx, sx), (mw, sw), scale = quantize_operands(x, w, p.n)
    ax = mx.astype(jnp.float32) * sx.astype(jnp.float32)
    aw = mw.astype(jnp.float32) * sw.astype(jnp.float32)
    return (ax @ aw + noise) * scale


def _inject_pallas(x, w, p, noise):
    """Draft-tier fast path: the quantized exact GEMM runs int-packed
    (two int16 K-lanes per uint32 — half the operand bytes of f32)
    before the moment-matched noise is applied.  Integer-exact, so it
    bit-matches the reference body (asserted in the fused parity sweep).
    """
    from repro.kernels.packed_matmul import pack_i16_pairs, packed_matmul_pallas

    (mx, sx), (mw, sw), scale = quantize_operands(x, w, p.n)
    qa = mx.astype(jnp.int32) * sx.astype(jnp.int32)
    qw = mw.astype(jnp.int32) * sw.astype(jnp.int32)
    pa = pack_i16_pairs(qa, axis=1)
    pb = pack_i16_pairs(qw, axis=0)
    out = packed_matmul_pallas(pa, pb, **_tile_kw(p))
    return (out + noise) * scale


def _fakequant_ref(x, w, p):
    xq = quantization.fake_quant(x, bits=p.n)
    wq = quantization.fake_quant(w, bits=p.n)
    return xq @ wq


register_mode(ModeSpec(
    name="exact",
    reference=_exact_ref,
    description="plain f32 matmul (baseline)",
))
register_mode(ModeSpec(
    name="bitexact",
    reference=_bitexact_ref,
    pallas=_bitexact_pallas,
    differentiable=False,
    exact_products=True,
    description="faithful paper semantics via the (2^n, 2^n) product LUT",
))
register_mode(ModeSpec(
    name="lowrank",
    reference=_lowrank_ref,
    pallas=_lowrank_pallas,
    differentiable=False,
    description="exact GEMM + rank-r SVD error correction (MXU-friendly)",
))
register_mode(ModeSpec(
    name="seqmul",
    reference=_seqmul_ref,
    pallas=_seqmul_pallas,
    differentiable=False,
    exact_products=True,
    description="paper recurrence fused into the GEMM tile loop (no LUT, n <= 12)",
))
register_mode(ModeSpec(
    name="inject",
    reference=_inject_ref,
    prepare=_inject_prepare,
    pallas=_inject_pallas,
    needs_key=True,
    differentiable=False,
    description="moment-matched stochastic error injection (O(1) at scale)",
))
register_mode(ModeSpec(
    name="fakequant",
    reference=_fakequant_ref,
    description="straight-through fake quantization (QAT substrate)",
))
