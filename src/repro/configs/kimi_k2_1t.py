"""Kimi-K2 — trillion-parameter MoE, 384 experts top-8 (paper-table entry)
[arXiv:2501.kimi2; unverified].  Per the assignment: 61L, d_model=7168,
64 heads (GQA kv=8), per-expert d_ff=2048, vocab=163840.

Total parameters ~= 61 * 384 * 3 * 2048 * 7168 ≈ 1.03e12 (the "1T");
active ≈ 61 * (8 experts * 3 * 2048 * 7168 + attention) ≈ 30e9 ("a32b").
This is the FSDP stress config: it only fits 512 chips with parameters
sharded over both mesh axes.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,  # all-MoE FFNs
    vocab_size=163840,
    layer_pattern=("attn_global",),
    ffn_activation="silu",
    num_experts=384,
    num_experts_per_tok=8,
    moe_d_ff=2048,
    capacity_factor=1.0,  # dispatch buffers at 1T scale must stay tight
    rope_theta=50000.0,
    tie_embeddings=False,
)
