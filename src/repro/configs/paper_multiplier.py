"""The paper's own configuration: a compact LM whose MLP GEMMs run
through the segmented-carry-chain approximate multiplier in its faithful
bit-exact mode (n=8, t=4, fix-to-1 on) — the configuration used by the
error-metric benchmarks and the approximate-training example."""

import dataclasses

from repro.configs.base import ApproxConfig
from repro.configs.qwen3_0_6b import CONFIG as _QWEN3

CONFIG = dataclasses.replace(
    _QWEN3,
    name="paper-multiplier",
    approx=ApproxConfig(
        enabled=True, n=8, t=4, fix_to_1=True, mode="bitexact", targets=("mlp",)
    ),
)
