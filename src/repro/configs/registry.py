"""Config registry: ``get_config("yi-9b")`` etc., plus approx overrides.

``apply_approx(cfg, ...)`` deploys the paper's technique onto any
architecture (DESIGN.md §Arch-applicability: applicable to all 10 —
every family has GEMM-dominated projections)."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.configs.base import ApproxConfig, ModelConfig, SHAPES, ShapeConfig

__all__ = [
    "ARCHS", "get_config", "list_archs", "apply_approx", "apply_quality",
    "shapes_for", "SHAPES",
]

# arch-id -> module name under repro.configs
ARCHS = {
    "yi-9b": "yi_9b",
    "gemma-7b": "gemma_7b",
    "qwen3-0.6b": "qwen3_0_6b",
    "gemma2-9b": "gemma2_9b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "mamba2-130m": "mamba2_130m",
    "seamless-m4t-large-v2": "seamless_m4t_large",
    "paper-multiplier": "paper_multiplier",
}


def list_archs(include_paper: bool = False) -> list[str]:
    out = [a for a in ARCHS if a != "paper-multiplier"]
    if include_paper:
        out.append("paper-multiplier")
    return out


def get_config(name: str, **overrides) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    cfg: ModelConfig = mod.CONFIG
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def apply_approx(
    cfg: ModelConfig,
    *,
    n: int = 8,
    t: Optional[int] = None,
    mode: str = "inject",
    fix_to_1: bool = True,
    rank: int = 8,
    targets: tuple = ("mlp",),
    backend: str = "auto",
) -> ModelConfig:
    """Deploy the segmented-carry-chain approximate multiplier on ``cfg``.

    ``mode`` is validated against the engine's mode registry so a typo
    fails here (listing the valid names) rather than at trace time.  A
    ``t`` left ``None`` is resolved by the accuracy-configuration
    controller (``engine.config.default_t(n)`` — the balanced tier's
    cheapest valid split) instead of a hardcoded constant; for named
    tiers with per-GEMM-class selection use :func:`apply_quality`.
    """
    from repro.engine import config as engine_config  # lazy: configs stay leaf-light
    from repro.engine import modes as engine_modes

    engine_modes.get_mode(mode)
    if t is None:
        t = engine_config.default_t(n)
    return dataclasses.replace(
        cfg,
        approx=ApproxConfig(
            enabled=True, n=n, t=t, fix_to_1=fix_to_1, mode=mode, rank=rank,
            targets=targets, backend=backend,
        ),
    )


def apply_quality(cfg: ModelConfig, tier, *, n: int = 8, order: int = 1) -> ModelConfig:
    """Deploy a named quality tier (``repro.engine.config``) onto ``cfg``:
    the controller resolves each budgeted GEMM class to its cheapest
    valid splitting point and installs the per-target overrides."""
    from repro.engine import config as engine_config  # lazy import as above

    return engine_config.apply_quality(cfg, tier, n=n, order=order)


def shapes_for(cfg: ModelConfig) -> dict[str, ShapeConfig]:
    """The assigned shape cells that apply to this architecture.

    ``long_500k`` needs sub-quadratic attention -> only SSM/hybrid families.
    All archs have autoregressive decoders, so no decode-shape skips.
    """
    out = dict(SHAPES)
    if not cfg.sub_quadratic:
        out.pop("long_500k")
    return out
