"""RecurrentGemma-2B (Griffin) — RG-LRU + local attention, 2:1
[arXiv:2402.19427].  MQA (kv=1), head_dim=256, window 2048."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "attn_local"),
    ffn_activation="gelu",
    embed_scale=True,
    local_window=2048,
    lru_width=2560,
    conv_width=4,
    rope_theta=10000.0,
    tie_embeddings=True,
)
