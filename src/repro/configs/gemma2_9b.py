"""Gemma2-9B — local+global alternating attention, logit softcaps,
post-sublayer norms [arXiv:2408.00118]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    layer_pattern=("attn_local", "attn_global"),
    ffn_activation="gelu",
    use_post_norm=True,
    embed_scale=True,
    final_logit_softcap=30.0,
    attn_logit_softcap=50.0,
    local_window=4096,
    rope_theta=10000.0,
    tie_embeddings=True,
)
