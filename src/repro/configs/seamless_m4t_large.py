"""SeamlessM4T-large-v2 — encoder-decoder, multimodal [arXiv:2308.11596].
24 encoder + 24 decoder layers; the speech frontend is a stub
(frontend="frames": precomputed conformer-frame embeddings)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    layer_pattern=("attn_global",),
    ffn_activation="silu",
    encoder_layers=24,
    rope_theta=10000.0,
    frontend="frames",
    tie_embeddings=True,
)
