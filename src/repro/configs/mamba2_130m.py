"""Mamba2-130M — SSD (state-space duality), attention-free
[arXiv:2405.21060].  d_inner = 2*d_model, 24 heads of dim 64, state 128."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,  # no separate FFN: the SSD mixer is the whole block
    vocab_size=50280,
    layer_pattern=("ssd",),
    ssm_state=128,
    ssm_heads=24,
    ssm_head_dim=64,
    ssm_chunk=256,
    d_inner=1536,
    conv_width=4,
    tie_embeddings=True,
)
