"""Qwen2-VL-7B — M-RoPE, dynamic-resolution ViT frontend (stubbed)
[arXiv:2409.12191].  The assignment specifies the transformer backbone;
``input_specs`` provides precomputed patch embeddings for the vision
stream (frontend="patches")."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    layer_pattern=("attn_global",),
    ffn_activation="silu",
    use_mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    frontend="patches",
    tie_embeddings=False,
)
