"""Granite-3.0-1B-A400M — 32-expert top-8 MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=0,  # all-MoE FFNs
    vocab_size=49155,
    layer_pattern=("attn_global",),
    ffn_activation="silu",
    num_experts=32,
    num_experts_per_tok=8,
    moe_d_ff=512,
    rope_theta=10000.0,
    tie_embeddings=True,
)
