"""Model / training configuration schema.

Every assigned architecture is a ``ModelConfig`` instance in its own file
under ``repro/configs``; reduced smoke variants derive from the full ones
via ``reduced()``.  The paper's technique enters through ``ApproxConfig``:
any dense projection can route its GEMM through the segmented-carry-chain
approximate multiplier (see repro.engine for the mode registry and
backend dispatch).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = [
    "ApproxConfig", "LayerQuality", "ModelConfig", "ShapeConfig",
    "TrainConfig", "SHAPES",
]


@dataclasses.dataclass(frozen=True)
class LayerQuality:
    """One GEMM class's resolved accuracy selection.

    Produced by the ``repro.engine.config`` controller (quality tiers ->
    per-target (n, t) via the closed-form error models) and carried in
    ``ApproxConfig.overrides``; ``None`` mode/backend inherit the base
    ``ApproxConfig`` values.
    """

    target: str  # "mlp" | "attn" | "moe"
    n: int
    t: int
    mode: Optional[str] = None
    backend: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ApproxConfig:
    """Approximate-multiplier deployment for a model's GEMMs."""

    enabled: bool = False
    # (n, t) defaults are the ``balanced`` quality tier's mlp resolution
    # at the engine default bit-width (engine.config.default_t(8) == 4 —
    # pinned by tests); per-target selections ride in ``overrides``.
    n: int = 8  # operand magnitude bit-width
    t: int = 4  # carry-chain splitting point
    fix_to_1: bool = True
    # any name registered in repro.engine.modes ('exact' | 'bitexact' |
    # 'lowrank' | 'inject' | 'fakequant' built in): fakequant/inject scale
    # to 1000-node training (O(1) overhead); lowrank/bitexact are the
    # faithful inference paths.
    mode: str = "inject"
    rank: int = 8
    # which projections are approximated ('mlp', 'attn', 'moe')
    targets: tuple = ("mlp",)
    # engine backend for the targeted GEMMs ('auto' | 'reference' | 'pallas')
    backend: str = "auto"
    # per-target LayerQuality entries (engine.config.apply_quality);
    # call sites resolve them with for_target
    overrides: tuple = ()

    def for_target(self, target: str) -> "ApproxConfig":
        """The effective config for one GEMM class: the matching
        ``LayerQuality`` override folded in, or ``self`` unchanged."""
        for q in self.overrides:
            if q.target == target:
                return dataclasses.replace(
                    self,
                    n=q.n,
                    t=q.t,
                    mode=self.mode if q.mode is None else q.mode,
                    backend=self.backend if q.backend is None else q.backend,
                    overrides=(),
                )
        return self


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # block pattern, cycled over layers: entries in
    # {"attn_global", "attn_local", "rglru", "ssd"}
    layer_pattern: tuple = ("attn_global",)
    ffn_activation: str = "silu"  # silu -> SwiGLU, gelu -> GeGLU
    use_qk_norm: bool = False
    use_post_norm: bool = False  # gemma2-style post-sublayer RMSNorm
    embed_scale: bool = False  # multiply embeddings by sqrt(d_model) (gemma)
    final_logit_softcap: Optional[float] = None
    attn_logit_softcap: Optional[float] = None
    local_window: int = 4096
    rope_theta: float = 10000.0
    use_mrope: bool = False  # Qwen2-VL multimodal RoPE (3 sections)
    mrope_sections: tuple = (16, 24, 24)  # t/h/w halves of head_dim/2
    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # RG-LRU / SSD
    lru_width: int = 0
    conv_width: int = 4
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    d_inner: int = 0
    # encoder-decoder (audio family)
    encoder_layers: int = 0
    # frontend stub for vlm/audio: inputs arrive as precomputed embeddings
    frontend: Optional[str] = None  # "patches" | "frames"
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # substrate knobs
    remat: str = "full"  # none | dots | full
    scan_layers: bool = True
    # "xla": blockwise online-softmax in pure jnp (compiles everywhere,
    #        used by the CPU dry-run); "pallas": the VMEM-resident flash
    #        kernel (kernels/flash_attention.py) — native on TPU,
    #        interpret-mode on CPU.
    attn_impl: str = "xla"
    # Megatron-style sequence parallelism on the inter-block residual
    # stream: the remat-saved (B, S, D) activations are sharded over the
    # model axis (AG/RS at the TP-region boundaries are inferred by SPMD).
    # Required to fit kimi-k2's 1M-token train step (§Perf iteration 6).
    seq_shard_residuals: bool = False
    approx: ApproxConfig = ApproxConfig()

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends globally (long_500k eligibility)."""
        return all(k in ("rglru", "ssd", "attn_local") for k in self.layer_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def reduced(self, **over) -> "ModelConfig":
        """Smoke-test configuration of the same family."""
        small = dict(
            num_layers=max(2, len(self.layer_pattern)),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            local_window=8,
            num_experts=4 if self.num_experts else 0,
            num_experts_per_tok=min(2, self.num_experts_per_tok) if self.num_experts else 0,
            moe_d_ff=32 if self.num_experts else 0,
            lru_width=64 if self.lru_width else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_heads=8 if self.ssm_heads else 0,  # must equal d_inner/head_dim
            ssm_head_dim=16 if self.ssm_heads else 64,
            ssm_chunk=8,
            d_inner=128 if self.d_inner else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            mrope_sections=(2, 3, 3),
            name=self.name + "-smoke",
            dtype="float32",
            remat="none",
        )
        small.update(over)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    grad_accum: int = 1
    opt_state_bits: int = 32  # 32 | 8 (quantized Adam moments)
    grad_compress_bits: int = 0  # 0 = off, 8 = int8 error-feedback compression
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
