"""Continuous-batching serve subsystem (docs/serving.md).

Public surface:

* :class:`~repro.serve.request.Request` / ``synth_requests`` — what the
  scheduler consumes and the deterministic workload generator.
* :class:`~repro.serve.scheduler.ContinuousScheduler` /
  ``continuous_serve_loop`` — slot-based admission, per-row positions,
  per-row retirement.
* ``static_serve_loop`` — the legacy static-batch loop, kept as baseline
  and parity oracle.
* :class:`~repro.serve.stats.ServeStats` / ``ServeResult`` /
  ``SlotAccounting`` — what a run measures and returns.
* :class:`~repro.serve.policy.AdmissionPolicy` and its implementations
  (``StaticTier`` / ``SLOAdaptive`` / ``Reject``) — pluggable admission
  + accuracy-tier control for the open-loop clocked scheduler.
* :class:`~repro.serve.strategy.DecodeStrategy` and its implementations
  (``GreedyDecode`` / ``SelfSpeculative``) — the decode-round layer:
  plain greedy, or self-speculative decoding across quality tiers.
* :class:`~repro.serve.workload.WorkloadSpec` / ``preset_spec`` —
  traffic-realistic workload generation (arrival processes, long-tail
  lengths, tier mixes, abuse presets).
* :func:`~repro.serve.soak.run_soak` / ``SoakReport`` — the windowed
  soak harness auditing slot-accounting and tail-latency invariants.
"""

from repro.serve.policy import (
    AdmissionPolicy,
    LoadSnapshot,
    Reject,
    SLOAdaptive,
    StaticTier,
    TierSwitch,
    get_policy,
)
from repro.serve.request import Request, RequestStats, synth_requests
from repro.serve.scheduler import (
    ContinuousScheduler,
    continuous_serve_loop,
    static_serve_loop,
    supports_continuous,
)
from repro.serve.soak import SoakReport, probe_eos_id, run_soak
from repro.serve.stats import ServeResult, ServeStats, SlotAccounting
from repro.serve.strategy import (
    DecodeStrategy,
    GreedyDecode,
    RoundResult,
    RowView,
    SelfSpeculative,
    TierEngine,
    get_strategy,
)
from repro.serve.workload import Workload, WorkloadSpec, preset_spec

__all__ = [
    "Request",
    "RequestStats",
    "synth_requests",
    "ContinuousScheduler",
    "continuous_serve_loop",
    "static_serve_loop",
    "supports_continuous",
    "AdmissionPolicy",
    "LoadSnapshot",
    "TierSwitch",
    "StaticTier",
    "SLOAdaptive",
    "Reject",
    "get_policy",
    "DecodeStrategy",
    "GreedyDecode",
    "SelfSpeculative",
    "RoundResult",
    "RowView",
    "TierEngine",
    "get_strategy",
    "ServeResult",
    "ServeStats",
    "SlotAccounting",
    "Workload",
    "WorkloadSpec",
    "preset_spec",
    "SoakReport",
    "probe_eos_id",
    "run_soak",
]
