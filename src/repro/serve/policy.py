"""Pluggable admission policies: who gets a slot, and at which tier.

The scheduler used to hard-code both answers: every queued request is
admitted the moment a slot frees, at the one accuracy tier the pool was
built with.  That closed-loop shape makes the paper's headline property
— accuracy *configurability* — invisible under load: the knob exists
(``engine.config`` resolves (n, t) per tier) but nothing ever turns it.
This module extracts the decision into an :class:`AdmissionPolicy` the
open-loop scheduler consults once per control tick:

* :class:`StaticTier` — always admit, always the pool's tier.  This is
  bit-for-bit the pre-policy scheduler and stays the parity oracle.
* :class:`SLOAdaptive` — the accuracy knob under closed-loop control:
  degrade the serving tier one rung down the ladder (e.g. ``high ->
  balanced -> draft``) when queue depth or the rolling TTFT tail breach
  the SLO, and recover one rung when the pool has been healthy for a
  while.  Hysteresis (separate degrade/recover streak lengths plus a
  minimum dwell between switches) makes the switch sequence a
  deterministic function of the trace — no oscillation on the
  boundary.  Tier resolution is delegated to ``engine.config``: the
  ladder is validated against the registered tiers and each rung's
  (n, t) resolution / cycle-cost factor comes from the controller, so
  the policy can only serve statically-certified configurations.
* :class:`Reject` — load shedding: beyond a queue-depth bound new
  arrivals are refused outright.  The classic baseline an adaptive
  policy must beat on SLO attainment without shedding.

This is the software analogue of dynamic reconfiguration of approximate
multipliers (Vakili et al., arXiv:2310.10053): the same weights serve
every tier, so switching costs one jitted-function swap, not a model
reload — near-zero switching cost, exactly the hardware story.

Policies are *stateful per run* (``begin`` resets them) and observe the
stream of retirements (``observe``) to maintain their rolling latency
windows; ``tier`` / ``admit`` must stay pure functions of the policy
state and the :class:`LoadSnapshot` so a replayed trace replays the
decision sequence (pinned by ``tests/test_serve_policy.py``).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Optional

from repro.serve.request import Request, RequestStats
from repro.serve.stats import percentile

__all__ = [
    "AdmissionPolicy",
    "LoadSnapshot",
    "TierSwitch",
    "StaticTier",
    "SLOAdaptive",
    "Reject",
    "POLICIES",
    "get_policy",
]


@dataclasses.dataclass(frozen=True)
class LoadSnapshot:
    """What the scheduler can tell a policy at one control tick.

    Pure load facts only — latency history lives inside the policy (fed
    by ``observe``), so the snapshot stays cheap and the policy owns its
    own window semantics.  ``now_s`` is clock time (virtual seconds in
    the deterministic open-loop clock, wall seconds otherwise).
    """

    now_s: float
    step: int  # global decode steps executed so far
    queue_depth: int  # arrived requests waiting for a slot
    pending: int  # generated but not yet arrived (open loop)
    live_rows: int
    batch_size: int
    head_wait_s: float = 0.0  # how long the queue head has been waiting


@dataclasses.dataclass(frozen=True)
class TierSwitch:
    """One recorded tier transition (the autoscaling event stream)."""

    step: int
    now_s: float
    from_tier: str
    to_tier: str
    reason: str  # "degrade:<signal>" | "recover"


class AdmissionPolicy:
    """Base policy: admit everything at the pool's own tier.

    Subclasses override :meth:`tier` (which accuracy tier the pool
    should run at this control tick) and/or :meth:`admit` (whether the
    queue head gets the free slot).  ``enforces_tier_tags`` keeps the
    legacy sold-at-tier admission check: policies that *own* the tier
    (SLOAdaptive) turn it off, because a request sold at ``high`` being
    served at ``balanced`` under pressure is the feature, not a bug —
    the served tier is recorded per request instead.
    """

    name = "static"
    enforces_tier_tags = True
    _pool_tier: Optional[str] = None

    def begin(self, pool_tier: Optional[str]) -> None:
        """Reset per-run state; ``pool_tier`` is the pool's resolved tier."""
        self._pool_tier = pool_tier

    def tier(self, snap: LoadSnapshot) -> Optional[str]:
        """Tier to serve at for this control tick (None = pool base config)."""
        return self._pool_tier

    def admit(self, req: Request, snap: LoadSnapshot) -> bool:
        """Whether to seat ``req`` now; False sheds it (recorded, never served)."""
        return True

    def speculation(self, snap: LoadSnapshot) -> bool:
        """Whether a speculative pool should speculate this round.

        Only consulted on pools whose decode strategy speculates at all
        (a greedy pool ignores it).  The base policy always says yes —
        the strategy itself already falls back to plain decode when no
        live row wants speculation; :class:`SLOAdaptive` instead gates
        on the modeled gain at the tier it is currently serving.
        """
        return True

    def observe(self, rs: RequestStats) -> None:
        """Feed one retirement record (rolling-window latency signals)."""

    @property
    def switches(self) -> tuple:
        """Tier-switch events recorded so far, in order."""
        return ()


class StaticTier(AdmissionPolicy):
    """Today's behavior as a policy object: the closed-loop bit-match oracle."""

    name = "static"


class Reject(AdmissionPolicy):
    """Load-shedding baseline: refuse arrivals beyond a queue-depth bound.

    ``max_queue_depth`` defaults to ``depth_factor * batch_size`` —
    roughly "one full pool refill already waiting".  Shedding keeps the
    served requests' latency flat at the price of rejected traffic; an
    adaptive tier policy has to beat this on SLO attainment *without*
    turning users away.
    """

    name = "reject"

    def __init__(self, *, max_queue_depth: Optional[int] = None,
                 depth_factor: float = 4.0):
        if max_queue_depth is not None and max_queue_depth < 1:
            raise ValueError(f"max_queue_depth must be >= 1, got {max_queue_depth}")
        if depth_factor <= 0:
            raise ValueError(f"depth_factor must be > 0, got {depth_factor}")
        self.max_queue_depth = max_queue_depth
        self.depth_factor = depth_factor

    def admit(self, req: Request, snap: LoadSnapshot) -> bool:
        bound = self.max_queue_depth
        if bound is None:
            bound = max(1, int(self.depth_factor * snap.batch_size))
        return snap.queue_depth <= bound


class SLOAdaptive(AdmissionPolicy):
    """SLO-closed-loop tier control with hysteresis.

    Control signals, evaluated once per tick against the target:

    * **Queue pressure** — ``queue_depth > queue_high * batch_size``
      (burst backpressure shows up here first);
    * **Tail latency** — rolling-window TTFT ``pctl`` percentile above
      ``slo_ttft_s`` (the lagging confirmation).

    A tick is a *breach* when either fires, *healthy* when the queue is
    back under ``queue_low * batch_size`` and the TTFT tail is within
    SLO.  ``degrade_after`` consecutive breaches move one rung down the
    ``ladder`` (toward cheaper tiers), ``recover_after`` consecutive
    healthy ticks move one rung up; every switch re-arms a
    ``min_dwell_ticks`` refractory window during which no further
    switch can happen.  Degrading needs a short streak (react to the
    burst), recovering a long one (don't flap on the first quiet step)
    — the asymmetry plus the dwell is the hysteresis that makes the
    switch sequence deterministic and oscillation-free on a seeded
    trace.

    The ladder is validated against ``engine.config`` at construction
    and each rung's controller resolution is pre-computed
    (``resolutions``), so an unregistered or uncertifiable tier fails
    fast, not mid-burst.
    """

    name = "slo-adaptive"
    enforces_tier_tags = False  # the policy owns the served tier

    def __init__(
        self,
        *,
        slo_ttft_s: float = 0.25,
        ladder: tuple = ("high", "balanced", "draft"),
        pctl: float = 95.0,
        queue_high: float = 2.0,
        queue_low: float = 0.5,
        degrade_after: int = 2,
        recover_after: int = 8,
        min_dwell_ticks: int = 8,
        window: int = 64,
        spec_draft_tier: str = "draft",
        spec_k: int = 4,
    ):
        from repro.engine import config as engine_config

        if slo_ttft_s <= 0:
            raise ValueError(f"slo_ttft_s must be > 0, got {slo_ttft_s}")
        if len(ladder) < 2:
            raise ValueError(f"ladder needs >= 2 tiers to adapt, got {ladder!r}")
        if not 0 < queue_low <= queue_high:
            raise ValueError(
                f"need 0 < queue_low <= queue_high, got {queue_low}/{queue_high}"
            )
        if degrade_after < 1 or recover_after < 1 or min_dwell_ticks < 0:
            raise ValueError("degrade_after/recover_after must be >= 1, "
                             "min_dwell_ticks >= 0")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        # canonicalize + resolve every rung through the controller now:
        # the ladder can only name registered tiers whose (n, t) the
        # engine.config controller certifies.
        self.ladder = tuple(engine_config.get_tier(t).name for t in ladder)
        self.resolutions = {
            t: engine_config.resolve_tier(t) for t in self.ladder
        }
        self.slo_ttft_s = slo_ttft_s
        self.pctl = pctl
        self.queue_high, self.queue_low = queue_high, queue_low
        self.degrade_after, self.recover_after = degrade_after, recover_after
        self.min_dwell_ticks = min_dwell_ticks
        self.window = window
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.spec_draft_tier = engine_config.get_tier(spec_draft_tier).name
        self.spec_k = spec_k
        self.begin(None)

    def begin(self, pool_tier: Optional[str]) -> None:
        self._pool_tier = pool_tier
        # start at the pool's rung when it sits on the ladder, else at the
        # most accurate rung — degradation is something load must earn
        self._rung = self.ladder.index(pool_tier) if pool_tier in self.ladder else 0
        self._ttft = collections.deque(maxlen=self.window)
        self._breaches = 0
        self._healthy = 0
        self._ticks = 0
        self._last_switch_tick = -(10**9)  # no refractory window at start
        self._switches: list = []

    def observe(self, rs: RequestStats) -> None:
        self._ttft.append(rs.ttft_s)

    def _signals(self, snap: LoadSnapshot) -> tuple:
        tail = percentile(self._ttft, self.pctl)
        queue_hot = snap.queue_depth > self.queue_high * snap.batch_size
        ttft_hot = tail is not None and tail > self.slo_ttft_s
        calm = (snap.queue_depth <= self.queue_low * snap.batch_size
                and not ttft_hot)
        reason = "queue" if queue_hot else "ttft"
        return queue_hot or ttft_hot, calm, reason

    def tier(self, snap: LoadSnapshot) -> Optional[str]:
        self._ticks += 1
        breach, calm, reason = self._signals(snap)
        self._breaches = self._breaches + 1 if breach else 0
        self._healthy = self._healthy + 1 if calm else 0
        dwelling = self._ticks - self._last_switch_tick <= self.min_dwell_ticks
        if not dwelling:
            if (breach and self._breaches >= self.degrade_after
                    and self._rung < len(self.ladder) - 1):
                self._switch(snap, self._rung + 1, f"degrade:{reason}")
            elif (calm and self._healthy >= self.recover_after
                    and self._rung > 0):
                self._switch(snap, self._rung - 1, "recover")
        return self.ladder[self._rung]

    def speculation(self, snap: LoadSnapshot) -> bool:
        """Speculate only while the modeled gain at the *currently served*
        rung beats plain decode: the closed-form accept-rate bound
        (``engine.config.accept_rate_estimate``) and the gate-delay cost
        model decide, so a pool already degraded to the draft rung stops
        speculating against itself (gain exactly 1.0) instead of burning
        k wasted proposal steps per round.  Deterministic: a pure
        function of the rung, so a replayed trace replays the decisions."""
        from repro.engine.config import speculation_gain

        return speculation_gain(
            self.spec_draft_tier, self.ladder[self._rung], self.spec_k
        ) > 1.0

    def _switch(self, snap: LoadSnapshot, rung: int, reason: str) -> None:
        self._switches.append(TierSwitch(
            step=snap.step, now_s=snap.now_s,
            from_tier=self.ladder[self._rung], to_tier=self.ladder[rung],
            reason=reason,
        ))
        self._rung = rung
        self._last_switch_tick = self._ticks
        self._breaches = self._healthy = 0

    @property
    def switches(self) -> tuple:
        return tuple(self._switches)


POLICIES = {
    "static": StaticTier,
    "slo-adaptive": SLOAdaptive,
    "reject": Reject,
}


def get_policy(policy, **kwargs) -> AdmissionPolicy:
    """Resolve a policy name (or pass an instance through) for the CLIs."""
    if isinstance(policy, AdmissionPolicy):
        if kwargs:
            raise ValueError("cannot pass policy kwargs with a policy instance")
        return policy
    try:
        cls = POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown admission policy {policy!r}; known: {sorted(POLICIES)}"
        ) from None
    return cls(**kwargs)
