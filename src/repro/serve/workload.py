"""Traffic-realistic workload generation: arrivals, length tails, tier mixes.

Every serve number before this module came from a ~10-request uniform
draw (``synth_requests``), which makes the ROADMAP's "heavy traffic"
claims unfalsifiable: uniform mixes never exercise bursty admission
churn, long-tail prompt skew, or abusive clients.  This module is the
seeded generator the soak harness (``repro.serve.soak``), the
``serve_soak`` benchmark suite, and the parameterized test sweep all
share — one spec + one seed fully determine the request trace
(:func:`trace_digest` pins that down byte for byte).

The knobs, each a small named model rather than a magic constant:

* **Arrival process** — ``immediate`` (closed-loop, everything queued at
  t=0: the legacy behavior), ``poisson`` (open-loop steady traffic at
  ``rate_rps``), or ``bursty`` (a 2-state Markov-modulated Poisson
  process: an *on* state arriving ``burst_factor`` times faster than the
  off state, occupied ``burst_fraction`` of the time — the classic model
  for flash-crowd traffic).
* **Length distributions** — per prompt length and generation budget:
  ``fixed`` (upper bound), ``min`` (lower bound), ``uniform``, ``zipf``
  (bounded power-law: mostly short with a heavy long tail), or
  ``lognormal`` (the shape real prompt-length histograms take).
* **Tier mix** — weighted assignment of ``Request.quality`` tags, so a
  soak can drive mixed sold-at-tier traffic through a pool (untagged
  requests ride any pool; tagged ones must match it).
* **Speculative fraction** — fraction of requests tagged
  ``Request.strategy == "speculative"``, drawn from a separate seeded
  stream so it never perturbs the other draws.  On a speculative pool
  this exercises mid-stream strategy switching (the churn and bursty
  presets tag a quarter of their traffic).
* **Abuse presets** — ``flood`` (every request pins the prompt bucket
  and the full generation budget: worst-case KV residency) and ``churn``
  (near-minimal budgets at high rate: most admissions retire
  immediately, maximizing slot-recycling pressure — and with
  ``eos_probe`` the soak harness stamps the pool's modal greedy first
  token as the trace's ``eos_id``, so the longer-budget tail retires by
  *true instant EOS*, not just budget exhaustion).

Requests are drawn lazily (:func:`iter_requests` / :func:`iter_windows`)
so a 100k-request soak never materializes the whole trace.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Iterator, Optional

import numpy as np

from repro.serve.request import Request

__all__ = [
    "ARRIVALS",
    "LENGTH_DISTS",
    "PRESETS",
    "Workload",
    "WorkloadSpec",
    "generate",
    "iter_requests",
    "iter_windows",
    "preset_spec",
    "tier_mix_label",
    "trace_digest",
]

ARRIVALS = ("immediate", "poisson", "bursty")
LENGTH_DISTS = ("fixed", "min", "uniform", "zipf", "lognormal")


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Everything that determines a workload besides the seed."""

    requests: int
    prompt_len: int  # upper prompt-length bound == the scheduler's bucket
    max_new: int  # upper generation-budget bound == the slot capacity
    vocab_size: int
    name: str = "custom"
    arrival: str = "poisson"
    rate_rps: float = 64.0  # long-run mean arrival rate (poisson + bursty)
    burst_factor: float = 8.0  # bursty: on-state rate multiplier (>= 1)
    burst_fraction: float = 0.15  # bursty: long-run fraction of time on
    mean_dwell_s: float = 0.25  # bursty: mean off-state dwell time
    prompt_dist: str = "zipf"
    gen_dist: str = "lognormal"
    min_prompt: int = 1
    min_gen: int = 1
    zipf_a: float = 1.8  # bounded-zipf exponent (> 1)
    lognormal_sigma: float = 0.8
    tier_mix: tuple = ()  # ((tier_name_or_None, weight), ...); () = untagged
    eos_id: Optional[int] = None
    # per-request TTFT SLO (seconds) stamped on every generated request;
    # None = no SLO.  The open-loop scheduler scores attainment against
    # it (repro.serve.policy drives tier degradation from the same
    # target).
    slo_ttft_s: Optional[float] = None
    # ask the soak harness to *probe* the pool's modal greedy first token
    # and use it as the trace's eos_id (repro.serve.soak.probe_eos_id) —
    # turns the churn preset's budget-capped retirement into true
    # instant-EOS retirement without hardcoding a weight-dependent token.
    eos_probe: bool = False
    # fraction of requests tagged ``strategy="speculative"`` (the rest
    # stay untagged).  On a speculative pool this drives mid-stream
    # strategy switching: rounds speculate only while some live row
    # carries the tag.  Drawn from a *separate* seeded stream, so
    # enabling it never perturbs the main-stream draws (arrivals,
    # lengths, budgets, tokens, tiers) — committed traces stay
    # byte-identical.
    spec_fraction: float = 0.0

    def __post_init__(self):
        if self.requests < 1:
            raise ValueError(f"requests must be >= 1, got {self.requests}")
        if self.arrival not in ARRIVALS:
            raise ValueError(f"arrival must be one of {ARRIVALS}, got {self.arrival!r}")
        for label, dist in (("prompt_dist", self.prompt_dist), ("gen_dist", self.gen_dist)):
            if dist not in LENGTH_DISTS:
                raise ValueError(f"{label} must be one of {LENGTH_DISTS}, got {dist!r}")
        if not 1 <= self.min_prompt <= self.prompt_len:
            raise ValueError(
                f"need 1 <= min_prompt <= prompt_len, got {self.min_prompt}/{self.prompt_len}"
            )
        if not 1 <= self.min_gen <= self.max_new:
            raise ValueError(f"need 1 <= min_gen <= max_new, got {self.min_gen}/{self.max_new}")
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")
        if self.burst_factor < 1.0:
            raise ValueError(f"burst_factor must be >= 1, got {self.burst_factor}")
        if not 0.0 < self.burst_fraction < 1.0:
            raise ValueError(f"burst_fraction must be in (0, 1), got {self.burst_fraction}")
        if self.zipf_a <= 1.0:
            raise ValueError(f"zipf_a must be > 1, got {self.zipf_a}")
        for tier, weight in self.tier_mix:
            if tier is not None and not isinstance(tier, str):
                raise ValueError(f"tier_mix names must be str or None, got {tier!r}")
            if not weight > 0:
                raise ValueError(f"tier_mix weight for {tier!r} must be > 0, got {weight}")
        if self.slo_ttft_s is not None and self.slo_ttft_s <= 0:
            raise ValueError(f"slo_ttft_s must be > 0, got {self.slo_ttft_s}")
        if not 0.0 <= self.spec_fraction <= 1.0:
            raise ValueError(
                f"spec_fraction must be in [0, 1], got {self.spec_fraction}"
            )


# Named traffic shapes: overrides applied on top of the caller's sizes.
PRESETS: dict[str, dict] = {
    # open-loop steady state: memoryless arrivals, uniform lengths
    "steady": {"arrival": "poisson", "prompt_dist": "uniform", "gen_dist": "uniform"},
    # flash crowds over long-tail lengths — the realistic stress mix
    "bursty": {"arrival": "bursty", "prompt_dist": "zipf", "gen_dist": "lognormal",
               "spec_fraction": 0.25},
    # abusive client: every request pins the bucket and the full budget
    "flood": {"arrival": "immediate", "prompt_dist": "fixed", "gen_dist": "fixed"},
    # abusive client: near-minimal budgets at high rate — most admissions
    # retire on the spot, maximizing slot-recycling churn.  Budgets are
    # zipf from 1 (mostly 1, short tail above it) and eos_probe asks the
    # soak harness to stamp the pool's modal greedy first token as the
    # trace's eos_id, so the tail rows retire by *true instant EOS*
    # rather than budget exhaustion — real abusive-client behavior, not
    # just its deterministic stand-in.
    "churn": {"arrival": "poisson", "rate_rps": 256.0, "prompt_dist": "zipf",
              "gen_dist": "zipf", "min_gen": 1, "eos_probe": True,
              "spec_fraction": 0.25},
}


def preset_spec(
    name: str,
    *,
    requests: int,
    prompt_len: int,
    max_new: int,
    vocab_size: int,
    tier_mix: tuple = (),
    eos_id: Optional[int] = None,
    **overrides,
) -> WorkloadSpec:
    """A :class:`WorkloadSpec` from a named traffic preset (see PRESETS)."""
    if name not in PRESETS:
        raise ValueError(f"unknown workload preset {name!r}; known: {sorted(PRESETS)}")
    kw: dict = dict(PRESETS[name])
    kw.update(overrides)
    return WorkloadSpec(
        name=name, requests=requests, prompt_len=prompt_len, max_new=max_new,
        vocab_size=vocab_size, tier_mix=tuple(tier_mix), eos_id=eos_id, **kw,
    )


def tier_mix_label(tier_mix: tuple) -> str:
    """Stable row-key label for a tier mix, e.g. ``"balanced:3+none:1"``."""
    if not tier_mix:
        return "none"
    return "+".join(f"{t or 'none'}:{w:g}" for t, w in tier_mix)


class _Arrivals:
    """Stateful arrival clock: absolute seconds per request, in order."""

    def __init__(self, spec: WorkloadSpec, rng: np.random.Generator):
        self.spec, self.rng = spec, rng
        self.t = 0.0
        if spec.arrival == "bursty":
            f, bf = spec.burst_fraction, spec.burst_factor
            # split the long-run mean rate over the two states:
            #   (1-f) * rate_off + f * rate_off * bf == rate_rps
            self.rate_off = spec.rate_rps / ((1.0 - f) + f * bf)
            self.rate_on = self.rate_off * bf
            # dwell times chosen so the on-state long-run occupancy is f
            self.dwell_off = spec.mean_dwell_s
            self.dwell_on = spec.mean_dwell_s * f / (1.0 - f)
            self.on = False
            self.t_switch = float(rng.exponential(self.dwell_off))

    def next(self) -> float:
        spec = self.spec
        if spec.arrival == "immediate":
            return 0.0
        if spec.arrival == "poisson":
            self.t += float(self.rng.exponential(1.0 / spec.rate_rps))
            return self.t
        # bursty: Poisson within the current state, exponential state dwells
        while True:
            rate = self.rate_on if self.on else self.rate_off
            gap = float(self.rng.exponential(1.0 / rate))
            if self.t + gap <= self.t_switch:
                self.t += gap
                return self.t
            self.t = self.t_switch
            self.on = not self.on
            dwell = self.dwell_on if self.on else self.dwell_off
            self.t_switch = self.t + float(self.rng.exponential(dwell))


def _sample_length(rng: np.random.Generator, dist: str, lo: int, hi: int,
                   spec: WorkloadSpec) -> int:
    if dist == "fixed":
        return hi
    if dist == "min":
        return lo
    if dist == "uniform":
        return int(rng.integers(lo, hi + 1))
    if dist == "zipf":
        # bounded power law anchored at lo: mostly lo, heavy tail toward hi
        return min(lo - 1 + int(rng.zipf(spec.zipf_a)), hi)
    # lognormal, median anchored a quarter of the way up the range
    mu = math.log(max(float(lo), hi / 4.0))
    draw = int(round(rng.lognormal(mu, spec.lognormal_sigma)))
    return min(max(draw, lo), hi)


def iter_requests(
    spec: WorkloadSpec, seed: int = 0
) -> Iterator[tuple[Request, float]]:
    """Yield ``(request, arrival_time_s)`` lazily, in arrival order.

    One ``default_rng(seed)`` with a fixed per-request draw order
    (arrival, prompt length, budget, tokens, tier), so the trace is a
    pure function of ``(spec, seed)`` — the deterministic-replay
    guarantee the soak harness and the BENCH metadata lean on.  The
    ``spec_fraction`` strategy tag draws come from a *separate* child
    stream (and only when the fraction is nonzero), so turning
    speculation on or off in a preset never shifts the main-stream
    draws above.
    """
    rng = np.random.default_rng(seed)
    spec_rng = (
        np.random.default_rng(np.random.SeedSequence([seed, 0x5BEC]))
        if spec.spec_fraction > 0 else None
    )
    arrivals = _Arrivals(spec, rng)
    if spec.tier_mix:
        tiers = [t for t, _ in spec.tier_mix]
        w = np.asarray([w for _, w in spec.tier_mix], np.float64)
        probs = w / w.sum()
    for i in range(spec.requests):
        t = arrivals.next()
        length = _sample_length(rng, spec.prompt_dist, spec.min_prompt, spec.prompt_len, spec)
        budget = _sample_length(rng, spec.gen_dist, spec.min_gen, spec.max_new, spec)
        tokens = rng.integers(0, spec.vocab_size, size=length).astype(np.int32)
        quality = tiers[int(rng.choice(len(tiers), p=probs))] if spec.tier_mix else None
        strategy = (
            "speculative"
            if spec_rng is not None and spec_rng.random() < spec.spec_fraction
            else None
        )
        yield Request(id=i, tokens=tokens, max_new=budget, eos_id=spec.eos_id,
                      quality=quality, slo_ttft_s=spec.slo_ttft_s,
                      strategy=strategy), t


def iter_windows(
    spec: WorkloadSpec, seed: int = 0, window_size: int = 256
) -> Iterator[tuple[list[Request], list[float]]]:
    """Chunk :func:`iter_requests` into bounded-memory windows.

    Yields ``(requests, arrival_times_s)`` lists of at most
    ``window_size`` entries; only one window is ever materialized.
    """
    if window_size < 1:
        raise ValueError(f"window_size must be >= 1, got {window_size}")
    reqs: list[Request] = []
    times: list[float] = []
    for req, t in iter_requests(spec, seed):
        reqs.append(req)
        times.append(t)
        if len(reqs) == window_size:
            yield reqs, times
            reqs, times = [], []
    if reqs:
        yield reqs, times


def trace_digest(spec: WorkloadSpec, seed: int = 0) -> str:
    """SHA-256 over the full request trace (ids, tokens, budgets, tiers,
    arrival times) — byte-identical traces ⇔ identical digests.  Streams
    over :func:`iter_requests`, so it is memory-bounded too."""
    h = hashlib.sha256()
    h.update(repr((spec, seed)).encode())
    for req, t in iter_requests(spec, seed):
        h.update(np.int64(req.id).tobytes())
        h.update(np.int64(req.prompt_len).tobytes())
        h.update(req.tokens.tobytes())
        h.update(np.int64(req.max_new).tobytes())
        h.update(np.int64(-1 if req.eos_id is None else req.eos_id).tobytes())
        h.update((req.quality or "").encode() + b"\0")
        h.update((req.strategy or "").encode() + b"\0")
        h.update(np.float64(t).tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class Workload:
    """A fully materialized draw — for tests and small benchmark runs;
    soaks should stream :func:`iter_windows` instead."""

    spec: WorkloadSpec
    seed: int
    requests: tuple  # of Request, arrival order
    arrivals_s: tuple  # of float, nondecreasing

    @property
    def offered_rps(self) -> float:
        """Mean offered arrival rate of this draw (inf for immediate)."""
        span = self.arrivals_s[-1] - self.arrivals_s[0] if len(self.arrivals_s) > 1 else 0.0
        return len(self.requests) / span if span > 0 else float("inf")


def generate(spec: WorkloadSpec, seed: int = 0) -> Workload:
    """Materialize one workload draw."""
    reqs, times = [], []
    for req, t in iter_requests(spec, seed):
        reqs.append(req)
        times.append(t)
    return Workload(spec=spec, seed=seed, requests=tuple(reqs), arrivals_s=tuple(times))
