"""Soak harness: stream a workload through a scheduler, audit every window.

This is the falsifier behind the ROADMAP's "heavy traffic" claims: tens
of thousands of :mod:`repro.serve.workload` requests stream through
:class:`~repro.serve.scheduler.ContinuousScheduler` (or the static
baseline) in **bounded-memory windows**, and after every window the
driver audits the invariants a slot-pool scheduler must keep under
realistic traffic:

* **Slot conservation** — the scheduler's own
  :class:`~repro.serve.stats.SlotAccounting` ledger must balance
  (``seated == retired``: no slot leaks) and every window request must
  be served exactly once (no losses, no duplicates across windows).
* **Monotone per-row positions** — per-slot KV write indices advance by
  exactly one physical slot per decode step and stay inside the cache
  (``position_violations == 0``, counted inside the decode loop itself).
* **Bounded outputs** — every retired request emitted between 1 and its
  budget of tokens.
* **Tail-latency stability** — per-window TTFT p99/p999; the drift of
  later windows' p99 against the first window is the leak detector a
  counter can't express (a slow leak shows up as monotonically rising
  tails long before anything crashes).
* **Parity spot-checks** — sampled request ids are re-served alone,
  unpadded, through the static oracle and must bit-match the soak
  stream.  Only on *exact* continuous pools: the static loop's
  shared-``arange`` positions make its own padded streams diverge from
  unpadded by construction, and approximate tiers quantize with
  batch-dependent artifacts, so their bit-parity is only defined
  batch-for-batch (continuous ≡ static at the same batch, pinned by
  ``tests/test_serve_scheduler.py``), not across batch compositions.

``run_soak`` returns a :class:`SoakReport`; ``report.ok`` is the CI
verdict and ``report.summary_row()`` the flat dict the ``serve_soak``
benchmark suite emits.  The CLI lives at ``repro.launch.soak``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.serve.policy import AdmissionPolicy, StaticTier, get_policy
from repro.serve.scheduler import (
    ContinuousScheduler,
    _apply_pool_quality,
    static_serve_loop,
)
from repro.serve.stats import percentile
from repro.serve.workload import WorkloadSpec, iter_requests, iter_windows, tier_mix_label

__all__ = ["WindowAudit", "SoakReport", "probe_eos_id", "run_soak"]


def probe_eos_id(
    model, params, spec: WorkloadSpec, *, seed: int = 0, probes: int = 5,
    quality=None,
) -> int:
    """The pool's *modal greedy first token* over a few probe prompts.

    EOS emission depends on model weights, so a workload cannot hardcode
    an ``eos_id`` that actually fires; probing the modal first token
    gives the trace an EOS the pool genuinely emits — the ``churn``
    preset uses it (``WorkloadSpec.eos_probe``) to turn budget-capped
    retirement into true instant-EOS retirement.  The probe draws its
    prompts from a decorrelated seed (so the soak trace itself is
    untouched) and serves each alone, unpadded, at the pool's tier;
    ties break toward the smallest token id for determinism.
    """
    if probes < 1:
        raise ValueError(f"probes must be >= 1, got {probes}")
    probe_spec = dataclasses.replace(
        spec, requests=probes, eos_id=None, eos_probe=False, tier_mix=(),
    )
    counts: dict[int, int] = {}
    for req, _ in iter_requests(probe_spec, seed + 7919):
        one = dataclasses.replace(req, max_new=1, eos_id=None, quality=None)
        alone = static_serve_loop(
            model, params, [one], batch_size=1, prompt_len=one.prompt_len,
            gen=1, warmup=False, quality=quality,
        )
        tok = int(alone.outputs[one.id][0])
        counts[tok] = counts.get(tok, 0) + 1
    return max(sorted(counts), key=lambda t: counts[t])


@dataclasses.dataclass(frozen=True)
class WindowAudit:
    """What one window measured and whether its invariants held."""

    index: int
    requests: int
    tokens_out: int
    decode_steps: int
    wall_s: float
    slot_utilization: float
    seated: int
    retired: int
    slot_leaks: int
    position_violations: int
    lost_requests: int
    duplicate_serves: int
    max_live: int
    offered_rps: float  # arrival rate offered by this window's slice
    ttft_p50_s: Optional[float]
    ttft_p99_s: Optional[float]
    ttft_p999_s: Optional[float]
    violations: tuple  # of str; empty == clean window
    rejected: int = 0  # requests the admission policy shed this window
    eos_retired: int = 0  # rows retired by EOS emission (vs budget)
    queue_delay_p99_s: Optional[float] = None  # open loop only
    tier_switches: int = 0  # pool tier transitions this window
    slo_total: int = 0
    slo_attained: int = 0


@dataclasses.dataclass(frozen=True)
class SoakReport:
    """Aggregate verdict of one soak run."""

    workload: str
    arrival: str
    tier_mix: str
    scheduler: str
    quality: str
    seed: int
    requests: int
    batch_size: int
    window_size: int
    windows: tuple  # of WindowAudit
    retirement_order: tuple  # request ids in global retirement order
    slot_reuse: tuple  # per-slot seat counts summed over windows
    ttft_drift_p99: float  # max later-window p99 / first-window p99
    drift_limit: Optional[float]
    spot_checks: int
    spot_check_failures: int
    violations: tuple  # of str, aggregated over windows + run-level checks
    loop: str = "closed"  # "closed" (queue drain) | "open" (arrival clocks)
    policy: str = ""  # admission policy name ("" = implicit static)
    strategy: str = ""  # pool decode strategy ("" = default greedy)

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def rejected(self) -> int:
        return sum(w.rejected for w in self.windows)

    @property
    def eos_retired(self) -> int:
        return sum(w.eos_retired for w in self.windows)

    @property
    def tier_switches(self) -> int:
        return sum(w.tier_switches for w in self.windows)

    @property
    def slo_attainment(self) -> Optional[float]:
        total = sum(w.slo_total for w in self.windows)
        if total == 0:
            return None
        return sum(w.slo_attained for w in self.windows) / total

    @property
    def tokens_out(self) -> int:
        return sum(w.tokens_out for w in self.windows)

    @property
    def wall_s(self) -> float:
        return sum(w.wall_s for w in self.windows)

    @property
    def decode_steps(self) -> int:
        return sum(w.decode_steps for w in self.windows)

    @property
    def slot_utilization(self) -> float:
        """Decode-step-weighted mean slot utilization over windows."""
        steps = sum(w.decode_steps for w in self.windows)
        if steps == 0:
            return 1.0
        return sum(w.slot_utilization * w.decode_steps for w in self.windows) / steps

    @property
    def reuse_spread(self) -> int:
        if not self.slot_reuse:
            return 0
        return int(max(self.slot_reuse) - min(self.slot_reuse))

    def summary_row(self) -> dict:
        """Flat dict for the ``serve_soak`` BENCH rows (and ``--json``)."""
        wall = self.wall_s
        ttft_all_p50 = percentile([w.ttft_p50_s for w in self.windows
                                   if w.ttft_p50_s is not None], 50)
        worst_p99 = max((w.ttft_p99_s for w in self.windows
                         if w.ttft_p99_s is not None), default=None)
        worst_p999 = max((w.ttft_p999_s for w in self.windows
                          if w.ttft_p999_s is not None), default=None)
        worst_queue_p99 = max((w.queue_delay_p99_s for w in self.windows
                               if w.queue_delay_p99_s is not None), default=None)
        att = self.slo_attainment
        return {
            "workload": self.workload,
            "arrival": self.arrival,
            "tier_mix": self.tier_mix,
            "scheduler": self.scheduler,
            "quality": self.quality,
            "loop": self.loop,
            "policy": self.policy or "static",
            "strategy": self.strategy or "greedy",
            "seed": self.seed,
            "requests": self.requests,
            "batch_size": self.batch_size,
            "window_size": self.window_size,
            "window_count": len(self.windows),
            "tokens_out": self.tokens_out,
            "decode_steps": self.decode_steps,
            "wall_s": round(wall, 4),
            "tokens_per_s": round(self.tokens_out / wall, 2) if wall > 0 else 0.0,
            "slot_utilization": round(self.slot_utilization, 4),
            "seated": sum(w.seated for w in self.windows),
            "retired": sum(w.retired for w in self.windows),
            "slot_leaks": sum(w.slot_leaks for w in self.windows),
            "position_violations": sum(w.position_violations for w in self.windows),
            "lost_requests": sum(w.lost_requests for w in self.windows),
            "duplicate_serves": sum(w.duplicate_serves for w in self.windows),
            "max_live": max((w.max_live for w in self.windows), default=0),
            "reuse_spread": self.reuse_spread,
            "ttft_p50_s": None if ttft_all_p50 is None else round(ttft_all_p50, 4),
            "ttft_p99_s_worst": None if worst_p99 is None else round(worst_p99, 4),
            "ttft_p999_s_worst": None if worst_p999 is None else round(worst_p999, 4),
            "ttft_drift_p99": round(self.ttft_drift_p99, 3),
            "rejected": self.rejected,
            "eos_retired": self.eos_retired,
            "tier_switches": self.tier_switches,
            "queue_delay_p99_s_worst": (
                None if worst_queue_p99 is None else round(worst_queue_p99, 4)
            ),
            "slo_attainment": None if att is None else round(att, 4),
            "spot_checks": self.spot_checks,
            "spot_check_failures": self.spot_check_failures,
            "violation_count": len(self.violations),
            "invariants_ok": 1.0 if self.ok else 0.0,
        }

    def describe(self) -> str:
        verdict = "PASS" if self.ok else f"FAIL ({len(self.violations)} violations)"
        return (
            f"[soak {self.workload}/{self.scheduler}] {self.requests} requests "
            f"in {len(self.windows)} windows of {self.window_size}: "
            f"{self.tokens_out} tokens, {self.slot_utilization:.0%} slot util, "
            f"ttft p99 drift {self.ttft_drift_p99:.2f}x, "
            f"{self.spot_checks - self.spot_check_failures}/{self.spot_checks} "
            f"parity spot-checks — {verdict}"
        )


def _audit_window(k, window_reqs, times, result, served_ids) -> WindowAudit:
    """Cross-check one window's ServeResult against what was offered.

    An admission policy may legitimately *shed* requests: a rejected id
    counts as handled exactly once (it must not read as lost, must not
    be served too, and still participates in cross-window duplicate
    detection), and ``served + rejected`` must cover the whole window —
    anything else is starvation, which is always a violation.
    """
    stats, acct = result.stats, result.accounting
    by_id = {r.id: r for r in window_reqs}
    out_ids = set(result.outputs)
    rej_ids = {rs.id for rs in result.rejected}
    handled = out_ids | rej_ids
    lost = sorted(set(by_id) - handled)
    alien = sorted(handled - set(by_id))
    dup = sorted((out_ids & rej_ids) | (handled & served_ids))
    served_ids |= handled

    violations = []
    if stats.requests + stats.rejected != len(window_reqs):
        violations.append(
            f"window {k}: served {stats.requests} + rejected {stats.rejected} "
            f"of {len(window_reqs)} requests"
        )
    if stats.starved != 0:
        violations.append(f"window {k}: {stats.starved} starved requests")
    if lost:
        violations.append(f"window {k}: lost requests {lost[:8]}")
    if alien:
        violations.append(f"window {k}: served ids never offered {alien[:8]}")
    if dup:
        violations.append(f"window {k}: ids served twice {dup[:8]}")
    if acct.slot_leaks != 0:
        violations.append(
            f"window {k}: slot leak — seated {acct.seated} != retired {acct.retired}"
        )
    if acct.position_violations != 0:
        violations.append(
            f"window {k}: {acct.position_violations} per-row write-position violations"
        )
    for rs in result.request_stats:
        req = by_id.get(rs.id)
        if req is not None and not 1 <= rs.tokens_out <= req.max_new:
            violations.append(
                f"window {k}: request {rs.id} emitted {rs.tokens_out} tokens "
                f"(budget {req.max_new})"
            )
            break  # one representative per window keeps the report readable

    span = times[-1] - times[0] if len(times) > 1 else 0.0
    return WindowAudit(
        index=k,
        requests=len(window_reqs),
        tokens_out=stats.tokens_out,
        decode_steps=stats.decode_steps,
        wall_s=stats.wall_s,
        slot_utilization=stats.slot_utilization,
        seated=acct.seated,
        retired=acct.retired,
        slot_leaks=acct.slot_leaks,
        position_violations=acct.position_violations,
        lost_requests=len(lost),
        duplicate_serves=len(dup),
        max_live=acct.max_live,
        offered_rps=len(window_reqs) / span if span > 0 else float("inf"),
        ttft_p50_s=percentile(stats.ttft_s, 50),
        ttft_p99_s=percentile(stats.ttft_s, 99),
        ttft_p999_s=percentile(stats.ttft_s, 99.9),
        violations=tuple(violations),
        rejected=stats.rejected,
        eos_retired=sum(
            1 for rs in result.request_stats if rs.finish_reason == "eos"
        ),
        queue_delay_p99_s=percentile(stats.queue_delay_s, 99),
        tier_switches=stats.tier_switches,
        slo_total=stats.slo_total,
        slo_attained=stats.slo_attained,
    )


def run_soak(
    model,
    params,
    spec: WorkloadSpec,
    *,
    batch_size: int,
    seed: int = 0,
    window_size: int = 256,
    scheduler: str = "continuous",
    quality=None,
    drift_limit: Optional[float] = None,
    spot_check: int = 0,
    progress: Optional[Callable[[WindowAudit], None]] = None,
    loop: str = "closed",
    policy=None,
    step_time_s: float = 0.01,
    clock: str = "virtual",
    strategy=None,
) -> SoakReport:
    """Stream ``spec``'s workload through the scheduler, window by window.

    Args:
      spec, seed: the workload draw (``workload.iter_windows(spec, seed)``).
        A spec with ``eos_probe`` set (the ``churn`` preset) first probes
        the pool's modal greedy first token (:func:`probe_eos_id`) and
        stamps it as the trace's ``eos_id``.
      batch_size: slot-pool size; the prompt bucket / generation capacity
        come from ``spec.prompt_len`` / ``spec.max_new``.
      window_size: requests per window; one window is materialized at a
        time and each runs to completion before it is audited.
      scheduler: ``"continuous"`` or ``"static"`` (the baseline loop;
        parity spot-checks are skipped there, see module docstring).
      quality: pool accuracy tier; tier-tagged requests in the workload
        are checked against it at admission (tier-enforcing policies).
      drift_limit: if set, a later window's TTFT p99 exceeding
        ``drift_limit`` times the first window's is a violation.
      spot_check: number of request ids (sampled deterministically from
        the seed) to re-serve alone, unpadded, and bit-compare.  Runs
        only on exact continuous pools (``quality=None``) under a
        non-tier-switching policy — see the module docstring for why
        approx/switched tiers have no cross-batch oracle; skipped
        checks report as ``spot_checks == 0``.
      progress: optional callback invoked with each :class:`WindowAudit`.
      loop: ``"closed"`` (legacy queue drain) or ``"open"`` — each
        window's arrival clocks (rebased to the window start) gate
        admission, measuring queue delay and backpressure.  Continuous
        scheduler only.
      policy: admission policy name or instance for the continuous
        scheduler (see :mod:`repro.serve.policy`); per-run state resets
        at every window boundary, so each window is one deterministic
        policy episode.
      step_time_s, clock: the open-loop clock (see
        :meth:`ContinuousScheduler.run`); the default virtual clock
        makes every soak timing deterministic.
      strategy: decode strategy name or instance for the continuous
        scheduler (see :mod:`repro.serve.strategy`).  ``None`` keeps the
        default greedy rounds; ``"speculative"`` self-speculates, and
        since speculative output bit-matches plain decode the parity
        spot-checks against the static oracle remain valid verbatim.
        Workload traces with a ``spec_fraction`` (churn/bursty presets)
        tag a fraction of requests, so a speculative soak exercises
        mid-stream strategy switching as tagged rows come and go.
    """
    if scheduler not in ("continuous", "static"):
        raise ValueError(f"scheduler must be continuous|static, got {scheduler!r}")
    if loop not in ("closed", "open"):
        raise ValueError(f"loop must be closed|open, got {loop!r}")
    if loop == "open" and scheduler != "continuous":
        raise ValueError("open-loop soak requires the continuous scheduler")
    if spot_check < 0:
        raise ValueError(f"spot_check must be >= 0, got {spot_check}")
    if scheduler == "static" and strategy not in (None, "greedy"):
        raise ValueError("decode strategies require the continuous scheduler")
    pol: Optional[AdmissionPolicy] = (
        get_policy(policy) if policy is not None else None
    )
    if spec.eos_probe and spec.eos_id is None:
        spec = dataclasses.replace(
            spec, eos_id=probe_eos_id(model, params, spec, seed=seed,
                                      quality=quality),
        )

    # a tier-switching policy serves sampled requests at pressure-dependent
    # tiers, so the unpadded static oracle is only valid under static
    # admission on an exact pool
    static_admission = pol is None or isinstance(pol, StaticTier)
    sample_ids: set = set()
    if (spot_check and scheduler == "continuous" and quality is None
            and static_admission):
        picker = np.random.default_rng(seed + 1)
        sample_ids = set(
            int(i) for i in picker.choice(
                spec.requests, size=min(spot_check, spec.requests), replace=False
            )
        )
    sampled: dict = {}  # id -> (Request, np.ndarray soak stream)

    sched = None
    if scheduler == "continuous":
        sched = ContinuousScheduler(
            model, params, batch_size=batch_size, prompt_len=spec.prompt_len,
            max_new=spec.max_new, quality=quality, strategy=strategy,
        )
        sched.warmup()
        pool_tier = sched.quality
    else:
        pool_tier = _apply_pool_quality(model, quality)[1]

    served_ids: set = set()
    windows: list[WindowAudit] = []
    violations: list[str] = []
    retirement_order: list[int] = []
    slot_reuse: Optional[list] = None

    for k, (window_reqs, times) in enumerate(iter_windows(spec, seed, window_size)):
        if scheduler == "continuous":
            if loop == "open":
                # window arrivals rebased to the window start: each window
                # is a self-contained open-loop episode
                arrivals = [t - times[0] for t in times]
                result = sched.run(
                    window_reqs, warmup=False, arrivals_s=arrivals,
                    policy=pol, step_time_s=step_time_s, clock=clock,
                )
            else:
                result = sched.run(window_reqs, warmup=False, policy=pol)
        else:
            result = static_serve_loop(
                model, params, window_reqs, batch_size=batch_size,
                prompt_len=spec.prompt_len, gen=spec.max_new,
                warmup=(k == 0), quality=quality,
            )
        audit = _audit_window(k, window_reqs, times, result, served_ids)
        windows.append(audit)
        violations.extend(audit.violations)
        retirement_order.extend(rs.id for rs in result.request_stats)
        acct = result.accounting
        if acct.slot_reuse:
            if slot_reuse is None:
                slot_reuse = [0] * len(acct.slot_reuse)
            for i, n in enumerate(acct.slot_reuse):
                slot_reuse[i] += n
        for req in window_reqs:
            if req.id in sample_ids and req.id in result.outputs:
                sampled[req.id] = (req, result.outputs[req.id])
        if progress is not None:
            progress(audit)

    # tail-latency drift: later windows against the first window's p99
    drift = 1.0
    baselines = [w.ttft_p99_s for w in windows if w.ttft_p99_s is not None]
    if len(baselines) > 1 and baselines[0] > 0:
        drift = max(p / baselines[0] for p in baselines[1:])
        if drift_limit is not None and drift > drift_limit:
            violations.append(
                f"ttft p99 drift {drift:.2f}x exceeds limit {drift_limit:.2f}x"
            )

    # parity spot-checks: the sampled soak streams must bit-match the same
    # request served alone, unpadded, through the static oracle
    failures = 0
    for rid in sorted(sampled):
        req, stream = sampled[rid]
        alone = static_serve_loop(
            model, params, [req], batch_size=1, prompt_len=req.prompt_len,
            gen=req.max_new, warmup=False, quality=quality,
        )
        if not np.array_equal(alone.outputs[rid], stream):
            failures += 1
            violations.append(
                f"spot-check: request {rid} soak stream diverged from the "
                f"unpadded single-request oracle"
            )

    return SoakReport(
        workload=spec.name,
        arrival=spec.arrival,
        tier_mix=tier_mix_label(spec.tier_mix),
        scheduler=scheduler,
        quality=pool_tier or "",
        seed=seed,
        requests=spec.requests,
        batch_size=batch_size,
        window_size=window_size,
        windows=tuple(windows),
        retirement_order=tuple(retirement_order),
        slot_reuse=tuple(slot_reuse or ()),
        ttft_drift_p99=drift,
        drift_limit=drift_limit,
        spot_checks=len(sampled),
        spot_check_failures=failures,
        violations=tuple(violations),
        loop=loop,
        policy=pol.name if pol is not None else "",
        strategy=sched.strategy.name if sched is not None else "",
    )
