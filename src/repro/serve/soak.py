"""Soak harness: stream a workload through a scheduler, audit every window.

This is the falsifier behind the ROADMAP's "heavy traffic" claims: tens
of thousands of :mod:`repro.serve.workload` requests stream through
:class:`~repro.serve.scheduler.ContinuousScheduler` (or the static
baseline) in **bounded-memory windows**, and after every window the
driver audits the invariants a slot-pool scheduler must keep under
realistic traffic:

* **Slot conservation** — the scheduler's own
  :class:`~repro.serve.stats.SlotAccounting` ledger must balance
  (``seated == retired``: no slot leaks) and every window request must
  be served exactly once (no losses, no duplicates across windows).
* **Monotone per-row positions** — per-slot KV write indices advance by
  exactly one physical slot per decode step and stay inside the cache
  (``position_violations == 0``, counted inside the decode loop itself).
* **Bounded outputs** — every retired request emitted between 1 and its
  budget of tokens.
* **Tail-latency stability** — per-window TTFT p99/p999; the drift of
  later windows' p99 against the first window is the leak detector a
  counter can't express (a slow leak shows up as monotonically rising
  tails long before anything crashes).
* **Parity spot-checks** — sampled request ids are re-served alone,
  unpadded, through the static oracle and must bit-match the soak
  stream.  Only on *exact* continuous pools: the static loop's
  shared-``arange`` positions make its own padded streams diverge from
  unpadded by construction, and approximate tiers quantize with
  batch-dependent artifacts, so their bit-parity is only defined
  batch-for-batch (continuous ≡ static at the same batch, pinned by
  ``tests/test_serve_scheduler.py``), not across batch compositions.

``run_soak`` returns a :class:`SoakReport`; ``report.ok`` is the CI
verdict and ``report.summary_row()`` the flat dict the ``serve_soak``
benchmark suite emits.  The CLI lives at ``repro.launch.soak``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.serve.scheduler import (
    ContinuousScheduler,
    _apply_pool_quality,
    static_serve_loop,
)
from repro.serve.stats import percentile
from repro.serve.workload import WorkloadSpec, iter_windows, tier_mix_label

__all__ = ["WindowAudit", "SoakReport", "run_soak"]


@dataclasses.dataclass(frozen=True)
class WindowAudit:
    """What one window measured and whether its invariants held."""

    index: int
    requests: int
    tokens_out: int
    decode_steps: int
    wall_s: float
    slot_utilization: float
    seated: int
    retired: int
    slot_leaks: int
    position_violations: int
    lost_requests: int
    duplicate_serves: int
    max_live: int
    offered_rps: float  # arrival rate offered by this window's slice
    ttft_p50_s: Optional[float]
    ttft_p99_s: Optional[float]
    ttft_p999_s: Optional[float]
    violations: tuple  # of str; empty == clean window


@dataclasses.dataclass(frozen=True)
class SoakReport:
    """Aggregate verdict of one soak run."""

    workload: str
    arrival: str
    tier_mix: str
    scheduler: str
    quality: str
    seed: int
    requests: int
    batch_size: int
    window_size: int
    windows: tuple  # of WindowAudit
    retirement_order: tuple  # request ids in global retirement order
    slot_reuse: tuple  # per-slot seat counts summed over windows
    ttft_drift_p99: float  # max later-window p99 / first-window p99
    drift_limit: Optional[float]
    spot_checks: int
    spot_check_failures: int
    violations: tuple  # of str, aggregated over windows + run-level checks

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def tokens_out(self) -> int:
        return sum(w.tokens_out for w in self.windows)

    @property
    def wall_s(self) -> float:
        return sum(w.wall_s for w in self.windows)

    @property
    def decode_steps(self) -> int:
        return sum(w.decode_steps for w in self.windows)

    @property
    def slot_utilization(self) -> float:
        """Decode-step-weighted mean slot utilization over windows."""
        steps = sum(w.decode_steps for w in self.windows)
        if steps == 0:
            return 1.0
        return sum(w.slot_utilization * w.decode_steps for w in self.windows) / steps

    @property
    def reuse_spread(self) -> int:
        if not self.slot_reuse:
            return 0
        return int(max(self.slot_reuse) - min(self.slot_reuse))

    def summary_row(self) -> dict:
        """Flat dict for the ``serve_soak`` BENCH rows (and ``--json``)."""
        wall = self.wall_s
        ttft_all_p50 = percentile([w.ttft_p50_s for w in self.windows
                                   if w.ttft_p50_s is not None], 50)
        worst_p99 = max((w.ttft_p99_s for w in self.windows
                         if w.ttft_p99_s is not None), default=None)
        worst_p999 = max((w.ttft_p999_s for w in self.windows
                          if w.ttft_p999_s is not None), default=None)
        return {
            "workload": self.workload,
            "arrival": self.arrival,
            "tier_mix": self.tier_mix,
            "scheduler": self.scheduler,
            "quality": self.quality,
            "seed": self.seed,
            "requests": self.requests,
            "batch_size": self.batch_size,
            "window_size": self.window_size,
            "window_count": len(self.windows),
            "tokens_out": self.tokens_out,
            "decode_steps": self.decode_steps,
            "wall_s": round(wall, 4),
            "tokens_per_s": round(self.tokens_out / wall, 2) if wall > 0 else 0.0,
            "slot_utilization": round(self.slot_utilization, 4),
            "seated": sum(w.seated for w in self.windows),
            "retired": sum(w.retired for w in self.windows),
            "slot_leaks": sum(w.slot_leaks for w in self.windows),
            "position_violations": sum(w.position_violations for w in self.windows),
            "lost_requests": sum(w.lost_requests for w in self.windows),
            "duplicate_serves": sum(w.duplicate_serves for w in self.windows),
            "max_live": max((w.max_live for w in self.windows), default=0),
            "reuse_spread": self.reuse_spread,
            "ttft_p50_s": None if ttft_all_p50 is None else round(ttft_all_p50, 4),
            "ttft_p99_s_worst": None if worst_p99 is None else round(worst_p99, 4),
            "ttft_p999_s_worst": None if worst_p999 is None else round(worst_p999, 4),
            "ttft_drift_p99": round(self.ttft_drift_p99, 3),
            "spot_checks": self.spot_checks,
            "spot_check_failures": self.spot_check_failures,
            "violation_count": len(self.violations),
            "invariants_ok": 1.0 if self.ok else 0.0,
        }

    def describe(self) -> str:
        verdict = "PASS" if self.ok else f"FAIL ({len(self.violations)} violations)"
        return (
            f"[soak {self.workload}/{self.scheduler}] {self.requests} requests "
            f"in {len(self.windows)} windows of {self.window_size}: "
            f"{self.tokens_out} tokens, {self.slot_utilization:.0%} slot util, "
            f"ttft p99 drift {self.ttft_drift_p99:.2f}x, "
            f"{self.spot_checks - self.spot_check_failures}/{self.spot_checks} "
            f"parity spot-checks — {verdict}"
        )


def _audit_window(k, window_reqs, times, result, served_ids) -> WindowAudit:
    """Cross-check one window's ServeResult against what was offered."""
    stats, acct = result.stats, result.accounting
    by_id = {r.id: r for r in window_reqs}
    out_ids = set(result.outputs)
    lost = sorted(set(by_id) - out_ids)
    alien = sorted(out_ids - set(by_id))
    dup = sorted(out_ids & served_ids)
    served_ids |= out_ids

    violations = []
    if stats.requests != len(window_reqs):
        violations.append(
            f"window {k}: served {stats.requests} of {len(window_reqs)} requests"
        )
    if lost:
        violations.append(f"window {k}: lost requests {lost[:8]}")
    if alien:
        violations.append(f"window {k}: served ids never offered {alien[:8]}")
    if dup:
        violations.append(f"window {k}: ids served twice {dup[:8]}")
    if acct.slot_leaks != 0:
        violations.append(
            f"window {k}: slot leak — seated {acct.seated} != retired {acct.retired}"
        )
    if acct.position_violations != 0:
        violations.append(
            f"window {k}: {acct.position_violations} per-row write-position violations"
        )
    for rs in result.request_stats:
        req = by_id.get(rs.id)
        if req is not None and not 1 <= rs.tokens_out <= req.max_new:
            violations.append(
                f"window {k}: request {rs.id} emitted {rs.tokens_out} tokens "
                f"(budget {req.max_new})"
            )
            break  # one representative per window keeps the report readable

    span = times[-1] - times[0] if len(times) > 1 else 0.0
    return WindowAudit(
        index=k,
        requests=len(window_reqs),
        tokens_out=stats.tokens_out,
        decode_steps=stats.decode_steps,
        wall_s=stats.wall_s,
        slot_utilization=stats.slot_utilization,
        seated=acct.seated,
        retired=acct.retired,
        slot_leaks=acct.slot_leaks,
        position_violations=acct.position_violations,
        lost_requests=len(lost),
        duplicate_serves=len(dup),
        max_live=acct.max_live,
        offered_rps=len(window_reqs) / span if span > 0 else float("inf"),
        ttft_p50_s=percentile(stats.ttft_s, 50),
        ttft_p99_s=percentile(stats.ttft_s, 99),
        ttft_p999_s=percentile(stats.ttft_s, 99.9),
        violations=tuple(violations),
    )


def run_soak(
    model,
    params,
    spec: WorkloadSpec,
    *,
    batch_size: int,
    seed: int = 0,
    window_size: int = 256,
    scheduler: str = "continuous",
    quality=None,
    drift_limit: Optional[float] = None,
    spot_check: int = 0,
    progress: Optional[Callable[[WindowAudit], None]] = None,
) -> SoakReport:
    """Stream ``spec``'s workload through the scheduler, window by window.

    Args:
      spec, seed: the workload draw (``workload.iter_windows(spec, seed)``).
      batch_size: slot-pool size; the prompt bucket / generation capacity
        come from ``spec.prompt_len`` / ``spec.max_new``.
      window_size: requests per window; one window is materialized at a
        time and each runs to completion before it is audited.
      scheduler: ``"continuous"`` or ``"static"`` (the baseline loop;
        parity spot-checks are skipped there, see module docstring).
      quality: pool accuracy tier; tier-tagged requests in the workload
        are checked against it at admission.
      drift_limit: if set, a later window's TTFT p99 exceeding
        ``drift_limit`` times the first window's is a violation.
      spot_check: number of request ids (sampled deterministically from
        the seed) to re-serve alone, unpadded, and bit-compare.  Runs
        only on exact continuous pools (``quality=None``) — see the
        module docstring for why approx tiers have no cross-batch
        oracle; skipped checks report as ``spot_checks == 0``.
      progress: optional callback invoked with each :class:`WindowAudit`.
    """
    if scheduler not in ("continuous", "static"):
        raise ValueError(f"scheduler must be continuous|static, got {scheduler!r}")
    if spot_check < 0:
        raise ValueError(f"spot_check must be >= 0, got {spot_check}")

    sample_ids: set = set()
    if spot_check and scheduler == "continuous" and quality is None:
        picker = np.random.default_rng(seed + 1)
        sample_ids = set(
            int(i) for i in picker.choice(
                spec.requests, size=min(spot_check, spec.requests), replace=False
            )
        )
    sampled: dict = {}  # id -> (Request, np.ndarray soak stream)

    sched = None
    if scheduler == "continuous":
        sched = ContinuousScheduler(
            model, params, batch_size=batch_size, prompt_len=spec.prompt_len,
            max_new=spec.max_new, quality=quality,
        )
        sched.warmup()
        pool_tier = sched.quality
    else:
        pool_tier = _apply_pool_quality(model, quality)[1]

    served_ids: set = set()
    windows: list[WindowAudit] = []
    violations: list[str] = []
    retirement_order: list[int] = []
    slot_reuse: Optional[list] = None

    for k, (window_reqs, times) in enumerate(iter_windows(spec, seed, window_size)):
        if scheduler == "continuous":
            result = sched.run(window_reqs, warmup=False)
        else:
            result = static_serve_loop(
                model, params, window_reqs, batch_size=batch_size,
                prompt_len=spec.prompt_len, gen=spec.max_new,
                warmup=(k == 0), quality=quality,
            )
        audit = _audit_window(k, window_reqs, times, result, served_ids)
        windows.append(audit)
        violations.extend(audit.violations)
        retirement_order.extend(rs.id for rs in result.request_stats)
        acct = result.accounting
        if acct.slot_reuse:
            if slot_reuse is None:
                slot_reuse = [0] * len(acct.slot_reuse)
            for i, n in enumerate(acct.slot_reuse):
                slot_reuse[i] += n
        for req in window_reqs:
            if req.id in sample_ids and req.id in result.outputs:
                sampled[req.id] = (req, result.outputs[req.id])
        if progress is not None:
            progress(audit)

    # tail-latency drift: later windows against the first window's p99
    drift = 1.0
    baselines = [w.ttft_p99_s for w in windows if w.ttft_p99_s is not None]
    if len(baselines) > 1 and baselines[0] > 0:
        drift = max(p / baselines[0] for p in baselines[1:])
        if drift_limit is not None and drift > drift_limit:
            violations.append(
                f"ttft p99 drift {drift:.2f}x exceeds limit {drift_limit:.2f}x"
            )

    # parity spot-checks: the sampled soak streams must bit-match the same
    # request served alone, unpadded, through the static oracle
    failures = 0
    for rid in sorted(sampled):
        req, stream = sampled[rid]
        alone = static_serve_loop(
            model, params, [req], batch_size=1, prompt_len=req.prompt_len,
            gen=req.max_new, warmup=False, quality=quality,
        )
        if not np.array_equal(alone.outputs[rid], stream):
            failures += 1
            violations.append(
                f"spot-check: request {rid} soak stream diverged from the "
                f"unpadded single-request oracle"
            )

    return SoakReport(
        workload=spec.name,
        arrival=spec.arrival,
        tier_mix=tier_mix_label(spec.tier_mix),
        scheduler=scheduler,
        quality=pool_tier or "",
        seed=seed,
        requests=spec.requests,
        batch_size=batch_size,
        window_size=window_size,
        windows=tuple(windows),
        retirement_order=tuple(retirement_order),
        slot_reuse=tuple(slot_reuse or ()),
        ttft_drift_p99=drift,
        drift_limit=drift_limit,
        spot_checks=len(sampled),
        spot_check_failures=failures,
        violations=tuple(violations),
    )
