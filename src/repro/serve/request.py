"""Serve-side request model: what enters the scheduler and what it reports.

A :class:`Request` is one generation job — a prompt (true, unpadded
token ids), a per-request generation budget, and an optional EOS id.
The scheduler retires a row the moment either terminates it, which is
exactly the behavior a static batch cannot express (a finished row there
burns dead decode steps until the whole batch drains).

:func:`synth_requests` builds the mixed-length / mixed-budget workload
shared by the CLI, the ``serve_throughput`` benchmark suite, and the
scheduler tests — one generator, so "same seed ⇒ same queue" holds
across all three.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["Request", "RequestStats", "synth_requests"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request (true prompt, no padding)."""

    id: int
    tokens: np.ndarray  # (L,) int32 prompt token ids, L >= 1
    max_new: int  # generation budget (>= 1)
    eos_id: Optional[int] = None  # retire early on this token, if set
    # accuracy tier the request was sold at (a repro.engine.config tier
    # name).  None = whatever the pool runs.  The scheduler checks the
    # tier against its own resolved engine config at admission — one
    # pool serves one tier, mismatches are rejected rather than served
    # at silently different quality.
    quality: Optional[str] = None

    def __post_init__(self):
        if len(self.tokens) < 1:
            raise ValueError(f"request {self.id}: empty prompt")
        if self.max_new < 1:
            raise ValueError(f"request {self.id}: max_new must be >= 1, got {self.max_new}")

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass(frozen=True)
class RequestStats:
    """Per-request serving record (wall times in seconds from run start)."""

    id: int
    prompt_len: int
    tokens_out: int
    admit_step: int  # global decode step at admission (0 == initial fill)
    ttft_s: float  # time to first token (queue wait + admission prefill)
    latency_s: float  # time to retirement
    finish_reason: str  # "budget" | "eos"


def synth_requests(
    count: int,
    *,
    prompt_len: int,
    gen: int,
    vocab_size: int,
    seed: int = 0,
    min_prompt: int = 4,
    vary_budget: bool = True,
    eos_id: Optional[int] = None,
    quality: Optional[str] = None,
    workload: Optional[str] = None,
    tier_mix: tuple = (),
) -> list[Request]:
    """Deterministic mixed workload: prompt lengths in [min_prompt, prompt_len],
    budgets in [1, gen] (or all ``gen`` when ``vary_budget=False``);
    ``quality`` tags every request with an accuracy tier name.

    ``workload`` opts into a :mod:`repro.serve.workload` traffic preset
    (``"steady"``/``"bursty"``/``"flood"``/``"churn"``): the request list
    is then drawn from that preset's arrival/length/tier models
    (``tier_mix`` weights tier tags; it defaults to tagging everything
    ``quality`` when that is set).  The default (``workload=None``) is
    the legacy uniform draw, byte-stable for a given seed — existing
    suites and committed BENCH baselines see identical queues.
    """
    if workload is not None:
        from repro.serve import workload as wl

        if not tier_mix and quality is not None:
            tier_mix = ((quality, 1.0),)
        spec = wl.preset_spec(
            workload, requests=count, prompt_len=prompt_len, max_new=gen,
            vocab_size=vocab_size, tier_mix=tier_mix, eos_id=eos_id,
            min_prompt=min(min_prompt, prompt_len),
        )
        return [req for req, _ in wl.iter_requests(spec, seed)]
    rng = np.random.default_rng(seed)
    out: list[Request] = []
    for i in range(count):
        lo = min(min_prompt, prompt_len)
        length = int(rng.integers(lo, prompt_len + 1))
        budget = int(rng.integers(1, gen + 1)) if vary_budget else gen
        out.append(Request(
            id=i,
            tokens=rng.integers(0, vocab_size, size=length).astype(np.int32),
            max_new=budget,
            eos_id=eos_id,
            quality=quality,
        ))
    return out
