"""Serve-side request model: what enters the scheduler and what it reports.

A :class:`Request` is one generation job — a prompt (true, unpadded
token ids), a per-request generation budget, and an optional EOS id.
The scheduler retires a row the moment either terminates it, which is
exactly the behavior a static batch cannot express (a finished row there
burns dead decode steps until the whole batch drains).

:func:`synth_requests` builds the mixed-length / mixed-budget workload
shared by the CLI, the ``serve_throughput`` benchmark suite, and the
scheduler tests — one generator, so "same seed ⇒ same queue" holds
across all three.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = ["Request", "RequestStats", "synth_requests"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request (true prompt, no padding)."""

    id: int
    tokens: np.ndarray  # (L,) int32 prompt token ids, L >= 1
    max_new: int  # generation budget (>= 1)
    eos_id: Optional[int] = None  # retire early on this token, if set
    # accuracy tier the request was sold at (a repro.engine.config tier
    # name).  None = whatever the pool runs.  Tier-enforcing admission
    # policies check the tier against the pool's resolved engine config
    # at admission — one pool serves one tier, mismatches are rejected
    # rather than served at silently different quality.  Under an
    # SLO-adaptive policy the tag is instead the *preferred* tier: the
    # pool may serve the request cheaper under pressure, and the tier
    # actually used is recorded in ``RequestStats.tier_served``.
    quality: Optional[str] = None
    # per-request TTFT service-level objective, in seconds.  None = no
    # SLO.  The open-loop scheduler scores attainment (first token
    # within the SLO, measured from *arrival*) over every offered
    # request carrying one — rejected requests count as missed, so a
    # load-shedding policy cannot game the metric.
    slo_ttft_s: Optional[float] = None
    # decode-strategy preference (repro.serve.strategy).  None = ride the
    # pool's strategy.  On a speculative pool, "greedy" opts the round
    # out of speculation when no live row wants it; "speculative" asks
    # for it.  Never changes the token stream — committed tokens are
    # always the verify engine's argmax — only the round shape/cost.
    strategy: Optional[str] = None

    def __post_init__(self):
        if len(self.tokens) < 1:
            raise ValueError(f"request {self.id}: empty prompt")
        if self.max_new < 1:
            raise ValueError(f"request {self.id}: max_new must be >= 1, got {self.max_new}")
        if self.slo_ttft_s is not None and self.slo_ttft_s <= 0:
            raise ValueError(
                f"request {self.id}: slo_ttft_s must be > 0, got {self.slo_ttft_s}"
            )
        if self.strategy not in (None, "greedy", "speculative"):
            raise ValueError(
                f"request {self.id}: unknown strategy {self.strategy!r} "
                f"(expected None, 'greedy' or 'speculative')"
            )

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)


@dataclasses.dataclass(frozen=True)
class RequestStats:
    """Per-request serving record.

    Closed loop: times are seconds from run start (the legacy
    semantics, unchanged).  Open loop: ``ttft_s`` and ``latency_s`` are
    re-based to the request's *arrival* time — what the client
    experiences, queueing included — and ``queue_delay_s`` separates the
    waiting component out (``ttft_s = queue_delay_s + admission cost``).
    """

    id: int
    prompt_len: int
    tokens_out: int
    admit_step: int  # global decode step at admission (0 == initial fill)
    ttft_s: float  # time to first token (queue wait + admission prefill)
    latency_s: float  # time to retirement
    finish_reason: str  # "budget" | "eos" | "rejected"
    arrival_s: float = 0.0  # open loop: arrival time on the run clock
    queue_delay_s: Optional[float] = None  # open loop: admission - arrival
    tier_served: str = ""  # accuracy tier actually served ("" = pool config)
    slo_ttft_s: Optional[float] = None  # the request's TTFT SLO, if any
    proposed: int = 0  # speculative rounds: draft tokens proposed for this row
    accepted: int = 0  # of those, accepted by the verify forward

    @property
    def rolled_back(self) -> int:
        """Draft tokens whose KV writes were abandoned (never committed)."""
        return self.proposed - self.accepted

    @property
    def accept_rate(self) -> Optional[float]:
        """Per-request draft acceptance, ``None`` when nothing was proposed
        (the no-data-is-not-zero convention of ``stats.percentile``)."""
        if self.proposed == 0:
            return None
        return self.accepted / self.proposed


def synth_requests(
    count: int,
    *,
    prompt_len: int,
    gen: int,
    vocab_size: int,
    seed: int = 0,
    min_prompt: int = 4,
    vary_budget: bool = True,
    eos_id: Optional[int] = None,
    quality: Optional[str] = None,
    workload: Optional[str] = None,
    tier_mix: tuple = (),
) -> list[Request]:
    """Deterministic mixed workload: prompt lengths in [min_prompt, prompt_len],
    budgets in [1, gen] (or all ``gen`` when ``vary_budget=False``);
    ``quality`` tags every request with an accuracy tier name.

    ``workload`` opts into a :mod:`repro.serve.workload` traffic preset
    (``"steady"``/``"bursty"``/``"flood"``/``"churn"``): the request list
    is then drawn from that preset's arrival/length/tier models
    (``tier_mix`` weights tier tags; it defaults to tagging everything
    ``quality`` when that is set).  The default (``workload=None``) is
    the legacy uniform draw, byte-stable for a given seed — existing
    suites and committed BENCH baselines see identical queues.
    """
    if workload is not None:
        from repro.serve import workload as wl

        if not tier_mix and quality is not None:
            tier_mix = ((quality, 1.0),)
        spec = wl.preset_spec(
            workload, requests=count, prompt_len=prompt_len, max_new=gen,
            vocab_size=vocab_size, tier_mix=tier_mix, eos_id=eos_id,
            min_prompt=min(min_prompt, prompt_len),
        )
        return [req for req, _ in wl.iter_requests(spec, seed)]
    rng = np.random.default_rng(seed)
    out: list[Request] = []
    for i in range(count):
        lo = min(min_prompt, prompt_len)
        length = int(rng.integers(lo, prompt_len + 1))
        budget = int(rng.integers(1, gen + 1)) if vary_budget else gen
        out.append(Request(
            id=i,
            tokens=rng.integers(0, vocab_size, size=length).astype(np.int32),
            max_new=budget,
            eos_id=eos_id,
            quality=quality,
        ))
    return out
