"""Decode strategies: how a slot pool turns live rows into tokens.

The per-step decode logic used to live in nested closures inside
``ContinuousScheduler.run()``; this module extracts it into a small
strategy layer so the *schedule* (admission, retirement, clocks,
policies) and the *decode discipline* (how many tokens one round
commits, at which tiers) vary independently:

* :class:`GreedyDecode` — one jitted pool decode per round, greedy
  argmax fused in.  Bit-for-bit the historical scheduler behavior.
* :class:`SelfSpeculative` — self-speculative decoding across quality
  tiers.  The paper's accuracy-configurable multiplier gives the pool a
  *free draft model*: the same weights decoded at a cheap tier (larger
  effective splitting point ``t``, deferred carries) propose ``k``
  tokens, then **one** batched ``(B, k+1)`` forward on the verify
  tier's engine scores all proposals together.  Every committed token
  is the *verify* engine's greedy argmax, so the output stream is
  bit-identical to plain decode on the verify engine — speculation
  only changes how many verify-quality tokens one round yields (and
  what it costs on the modeled clock).

Rollback is host-side bookkeeping, not a device operation: both phases
write the *same* physical KV slots (the verify forward overwrites every
draft-quality cache entry before its attention reads them — see
``models.attention``'s per-row ``cache_pos`` path), and a rejected
suffix is "rolled back" simply by not advancing the row's emitted
counter past it, so the next round's writes land on top of the stale
slots.  Key-position masking (queries only attend to cache slots at or
below their own position) keeps the stale suffix invisible meanwhile.

Engines (:class:`TierEngine`, :func:`build_tier_engine`) also live here:
one accuracy tier's jitted (admit, pool-prefill, decode, verify) bundle
over the shared slot pool cache, formerly the scheduler-private
``_TierEngine``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.steps import make_decode_step, make_prefill_step

__all__ = [
    "TierEngine",
    "build_tier_engine",
    "make_verify_step",
    "RowView",
    "RoundResult",
    "DecodeStrategy",
    "GreedyDecode",
    "SelfSpeculative",
    "STRATEGIES",
    "get_strategy",
]


def make_verify_step(model):
    """verify(params, caches, tokens (B, S), positions (B, S), starts (B,))
    -> (argmax (B, S) int32, caches).

    One multi-token forward over live caches: row ``i``'s ``S`` tokens
    occupy true positions ``positions[i]`` and write physical cache
    slots ``starts[i] .. starts[i] + S - 1``.  This is the speculative
    verify primitive — ``make_prefill_step`` cannot express it (it
    builds fresh caches and pins the write start to slot 0), and
    ``make_decode_step`` is single-token.
    """
    cfg = model.cfg

    def verify(params, caches, tokens, positions, starts):
        b, s = tokens.shape
        ctx = model.ctx()
        p = jnp.asarray(positions, jnp.int32)
        if cfg.use_mrope:
            p = jnp.broadcast_to(p[None], (3, b, s))
        hidden, new_caches, _ = model.forward(
            params, tokens, p, ctx, caches=caches,
            cache_pos=jnp.asarray(starts, jnp.int32),
        )
        logits = model.lm_head(params, hidden)
        return jnp.argmax(logits, -1).astype(jnp.int32), new_caches

    return verify


@dataclasses.dataclass(frozen=True)
class TierEngine:
    """One accuracy tier's jitted serving steps over the shared slot pool.

    Approximation only changes the forward math — KV cache shapes and
    dtypes are tier-independent — so every engine reads and writes the
    *same* physical pool cache, and switching the serving tier mid-run
    is a dict lookup plus (first visit) a jit compile.  This is the
    serving-layer analogue of reconfiguring an accuracy-configurable
    multiplier's splitting point in place: same hardware (weights +
    cache), different carry-chain cut, near-zero switching cost.
    """

    key: Optional[str]  # engine-cache key (canonical tier, None = pool base)
    name: Optional[str]  # canonical tier name (None = no tier applied)
    admit_step: object  # jitted single-row prefill + scatter + argmax
    prefill_pool: object  # jitted batched pool prefill
    decode: object  # jitted pool decode with fused greedy argmax
    verify: object  # jitted multi-token speculative verify forward
    cost_factor: float  # tier_cycle_factor: virtual clock cost per step


def build_tier_engine(model, capacity: int, *, name, key,
                      scatter_row) -> TierEngine:
    """Jit the (admit, pool-prefill, decode, verify) bundle for one tier.

    ``scatter_row(big, small, row)`` is the admission cache-scatter
    primitive (the scheduler owns it; injected to keep this module free
    of cache-layout knowledge).
    """
    prefill = make_prefill_step(model, capacity)
    decode = make_decode_step(model)
    verify = make_verify_step(model)

    # Admission, fused to one dispatch: single-row prefill + scatter
    # into the freed slot + greedy first token.
    def admit_step(params, caches, toks, pos, row):
        row_caches, logits = prefill(params, {"tokens": toks, "positions": pos})
        caches = scatter_row(caches, row_caches, row)
        tok0 = jnp.argmax(logits[0, -1], -1).astype(jnp.int32)
        return caches, tok0

    # Initial fill, when the queue covers every slot: one batched
    # prefill *is* the pool cache — no scatter at all.
    def prefill_pool(params, toks, pos):
        caches, logits = prefill(params, {"tokens": toks, "positions": pos})
        return caches, jnp.argmax(logits[:, -1], -1).astype(jnp.int32)

    # Decode with the greedy argmax fused in (one dispatch per step,
    # and only (B,) token ids cross back to the host).
    def decode_greedy(params, caches, tok, pos, write):
        logits, caches = decode(params, caches, tok, pos, write)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), caches

    from repro.engine.config import tier_cycle_factor

    return TierEngine(
        key=key,
        name=name,
        admit_step=jax.jit(admit_step, donate_argnums=1),
        prefill_pool=jax.jit(prefill_pool),
        decode=jax.jit(decode_greedy, donate_argnums=1),
        verify=jax.jit(verify, donate_argnums=1),
        cost_factor=tier_cycle_factor(name),
    )


@dataclasses.dataclass(frozen=True)
class RowView:
    """What a strategy may know about one live row.

    A host-side snapshot, not the slot itself: strategies compute
    position/write vectors and token streams from it but never mutate
    scheduler state — commitment (absorb/retire/EOS) stays with the
    scheduler, which is what makes a multi-token round's early stop
    (budget or EOS inside the committed run) safe.
    """

    index: int  # slot index in the pool
    prompt_len: int  # true (unpadded) prompt length
    emitted: int  # tokens emitted so far (>= 1: admission token counted)
    strategy: Optional[str] = None  # per-request tag (None = pool default)


@dataclasses.dataclass(frozen=True)
class RoundResult:
    """One decode round's outcome, as the scheduler consumes it.

    ``tokens[i]`` is the ordered token run committed to row ``i`` —
    every token is final (verify-engine argmax); the scheduler absorbs
    them one at a time so budget/EOS can cut the run short.  ``cost``
    is the round's modeled cost in exact-decode-step units (the
    virtual-clock charge); ``steps`` the number of model forwards.
    """

    tokens: dict  # row index -> list[int]
    caches: object
    steps: int
    cost: float
    proposed: int = 0  # draft tokens proposed this round
    accepted: int = 0  # draft tokens accepted by the verify forward
    per_row: dict = dataclasses.field(default_factory=dict)  # i -> (prop, acc)


class DecodeStrategy:
    """Protocol: one decode round over the live rows of a slot pool.

    ``decode_round(pool, engine, caches, cur_tok, rows, speculate=...)``
    returns a :class:`RoundResult`.  ``pool`` is the scheduler (read
    ``capacity`` / ``params`` / ``prompt_len``, call ``engine_for``);
    ``engine`` the tick's policy-selected :class:`TierEngine`;
    ``cur_tok`` the host-side ``(B, 1)`` array of each row's last
    committed token (strategies must not mutate it); ``rows`` the live
    :class:`RowView` snapshots.
    """

    name = "greedy"

    @property
    def extra_capacity(self) -> int:
        """Extra physical KV slots per row beyond ``prompt_len + max_new``."""
        return 0

    def admission_key(self, policy_key):
        """Engine key admissions (prefill) must run at, given the tick's
        policy-selected key.  Greedy admits at the serving tier; a
        speculative strategy admits at its verify tier so the cache
        prefix is verify-quality from the start."""
        return policy_key

    def warmup(self, pool) -> None:
        """Compile any strategy-specific steps outside the timed region."""

    def decode_round(self, pool, engine, caches, cur_tok, rows,
                     *, speculate: bool = True) -> RoundResult:
        raise NotImplementedError


class GreedyDecode(DecodeStrategy):
    """One pool decode per round: the historical behavior, bit for bit."""

    name = "greedy"

    def decode_round(self, pool, engine, caches, cur_tok, rows,
                     *, speculate: bool = True) -> RoundResult:
        B = cur_tok.shape[0]
        P = pool.prompt_len
        # per-row true position + physical write slot; dead lanes park at
        # the last physical slot with offset 0
        pos = np.full((B,), pool.capacity - 1, np.int32)
        write = np.full((B,), pool.capacity - 1, np.int32)
        for r in rows:
            pos[r.index] = r.prompt_len + r.emitted - 1
            write[r.index] = P + r.emitted - 1
        nxt, caches = engine.decode(
            pool.params, caches, jnp.asarray(cur_tok),
            jnp.asarray(pos), jnp.asarray(write),
        )
        nxt = np.asarray(nxt)
        return RoundResult(
            tokens={r.index: [int(nxt[r.index])] for r in rows},
            caches=caches, steps=1, cost=engine.cost_factor,
        )


class SelfSpeculative(DecodeStrategy):
    """k draft-tier proposal steps + one batched verify forward per round.

    Per live row with last committed token ``c`` at true position ``p0``
    (write slot ``w0``): the draft engine runs ``k`` chained single-token
    decodes producing proposals ``d_1 .. d_k``; the verify engine then
    runs one ``(B, k+1)`` forward over ``(c, d_1 .. d_k)`` at positions
    ``p0 .. p0+k`` writing slots ``w0 .. w0+k`` — overwriting every
    draft-quality cache entry with verify-quality state before its own
    attention reads them.  Position ``j``'s argmax is the verify
    engine's next token after prefix ``.. d_j``; the longest prefix
    where draft and verify agree is accepted and the first disagreement
    position contributes the verify token itself (the "bonus" token), so
    every round commits between 1 and k+1 verify-quality tokens and the
    stream bit-matches plain decode on the verify engine.

    ``verify_tier=None`` verifies at the tick's policy-selected engine
    (the pool tier under ``StaticTier``); a per-pool ``verify_tier``
    pins it.  Rows tagged ``strategy="greedy"`` opt out: a round
    speculates when some live row asked for it, or when no row carries
    a tag at all (pool-level ``--strategy speculative``).
    """

    name = "speculative"

    def __init__(self, k: int = 4, draft_tier: str = "draft",
                 verify_tier: Optional[str] = None):
        if k < 1:
            raise ValueError(f"speculation depth k must be >= 1, got {k}")
        from repro.engine.config import get_tier

        self.k = k
        self.draft_tier = get_tier(draft_tier).name
        self.verify_tier = (
            get_tier(verify_tier).name if verify_tier is not None else None
        )
        # draft == verify is degenerate but legal: accept rate exactly 1.0,
        # modeled gain exactly 1.0 (speculation naturally "off")
        self._greedy = GreedyDecode()

    @property
    def extra_capacity(self) -> int:
        # the verify forward writes up to slot (prompt_len + max_new - 2) + k
        # for a row one token short of budget; k spare slots cover it
        return self.k

    def admission_key(self, policy_key):
        return self.verify_tier if self.verify_tier is not None else policy_key

    def wants_speculation(self, rows: Sequence[RowView]) -> bool:
        tags = [r.strategy for r in rows if r.strategy is not None]
        if not tags:
            return True  # untagged pool: the CLI-level strategy rules
        return any(t == "speculative" for t in tags)

    def warmup(self, pool) -> None:
        """Compile draft decode + verify on throwaway caches."""
        B, cap = pool.batch_size, pool.capacity
        draft = pool.engine_for(self.draft_tier)
        verify = pool.engine_for(self.admission_key(pool.quality))
        caches = pool.model.init_caches(B, cap, pool._cache_dtype)
        zeros = jnp.zeros((B,), jnp.int32)
        _, caches = draft.decode(
            pool.params, caches, jnp.zeros((B, 1), jnp.int32), zeros, zeros)
        ver, caches = verify.verify(
            pool.params, caches, jnp.zeros((B, self.k + 1), jnp.int32),
            jnp.broadcast_to(jnp.arange(self.k + 1, dtype=jnp.int32)[None],
                             (B, self.k + 1)),
            zeros,
        )
        jax.block_until_ready(ver)

    def decode_round(self, pool, engine, caches, cur_tok, rows,
                     *, speculate: bool = True) -> RoundResult:
        verify_eng = (
            pool.engine_for(self.verify_tier)
            if self.verify_tier is not None else engine
        )
        if not speculate or not self.wants_speculation(rows):
            return self._greedy.decode_round(
                pool, verify_eng, caches, cur_tok, rows)
        draft_eng = pool.engine_for(self.draft_tier)
        B = cur_tok.shape[0]
        P, cap, k = pool.prompt_len, pool.capacity, self.k
        live = [r.index for r in rows]
        p0 = np.full((B,), cap - 1, np.int32)  # dead-lane park (offset 0)
        w0 = np.full((B,), cap - 1, np.int32)
        for r in rows:
            p0[r.index] = r.prompt_len + r.emitted - 1
            w0[r.index] = P + r.emitted - 1

        # ---- draft phase: k chained cheap-tier decodes propose d_1..d_k
        props = np.zeros((B, k), np.int32)
        tok = jnp.asarray(cur_tok)  # never mutate the scheduler's array
        for j in range(k):
            pos = np.where(p0 + j < cap, p0 + j, cap - 1).astype(np.int32)
            wrt = np.where(w0 + j < cap, w0 + j, cap - 1).astype(np.int32)
            # live rows never clip (emitted <= max_new - 1 so w0 + k < cap);
            # the where only re-parks dead lanes at the last slot
            nxt, caches = draft_eng.decode(
                pool.params, caches, tok, jnp.asarray(pos), jnp.asarray(wrt))
            props[:, j] = np.asarray(nxt)
            tok = nxt[:, None]

        # ---- verify phase: one (B, k+1) forward on the verify engine,
        # re-writing slots w0..w0+k with verify-quality KV
        vtok = np.concatenate([cur_tok, props], axis=1)  # (B, k+1)
        starts = w0.copy()
        vpos = p0[:, None] + np.arange(k + 1, dtype=np.int32)[None, :]
        live_set = frozenset(live)
        for i in range(B):
            if i not in live_set:
                # dead lane: park the whole window in the spare tail slots
                # (positions arange(k+1): causal, >= 1 visible key, no NaN)
                starts[i] = cap - (k + 1)
                vpos[i] = np.arange(k + 1, dtype=np.int32)
        ver, caches = verify_eng.verify(
            pool.params, caches, jnp.asarray(vtok), jnp.asarray(vpos),
            jnp.asarray(starts),
        )
        ver = np.asarray(ver)

        # ---- accept: longest agreeing prefix + the verify bonus token
        tokens: dict = {}
        per_row: dict = {}
        proposed = accepted = 0
        for r in rows:
            i = r.index
            a = 0
            while a < k and props[i, a] == ver[i, a]:
                a += 1
            tokens[i] = [int(t) for t in ver[i, : a + 1]]
            per_row[i] = (k, a)
            proposed += k
            accepted += a
        cost = k * draft_eng.cost_factor + verify_eng.cost_factor
        return RoundResult(
            tokens=tokens, caches=caches, steps=k + 1, cost=cost,
            proposed=proposed, accepted=accepted, per_row=per_row,
        )


STRATEGIES = {
    "greedy": GreedyDecode,
    "speculative": SelfSpeculative,
}


def get_strategy(strategy, **kwargs) -> DecodeStrategy:
    """Resolve a strategy name (or pass an instance through) for the CLIs."""
    if strategy is None:
        strategy = "greedy"
    if isinstance(strategy, DecodeStrategy):
        if kwargs:
            raise ValueError("cannot pass strategy kwargs with an instance")
        return strategy
    try:
        cls = STRATEGIES[strategy]
    except KeyError:
        raise ValueError(
            f"unknown decode strategy {strategy!r}; known: {sorted(STRATEGIES)}"
        ) from None
    return cls(**kwargs)
