"""Continuous-batching scheduler: slot-based admission, per-row retirement.

The serving core the ROADMAP's "heavy traffic" north star asks for.  A
fixed pool of ``batch_size`` *slots* shares one physical KV cache of
``prompt_len + max_new`` entries per slot; the decode step is jitted once
for the full pool and every global step advances all live rows together.
The continuous part is the slot lifecycle:

  queued -> admitted -> decoding -> retired -> (slot reused)

* **Admission** runs a single-row prefill of the new request (left-padded
  into the fixed prompt bucket, with *true* per-row position ids so pads
  are masked out of the cache) and scatters the resulting row cache into
  the pool cache at the free slot — surviving rows are untouched: no
  re-prefill, no re-batch barrier.
* **Decode** passes per-row position vectors (true position and physical
  write slot per row) to :func:`repro.train.steps.make_decode_step`, so
  rows sitting at different depths advance in one step.
* **Retirement** happens the step a row hits its budget or EOS; the freed
  slot is refilled from the queue before the next decode step.  A static
  batch, by contrast, burns dead decode steps on finished rows until the
  whole batch drains — that difference is the ``serve_throughput``
  benchmark's speedup column.

``static_serve_loop`` is the pre-continuous static-batch loop, kept as
the measured baseline and the parity oracle (it is exactly the old
``launch.serve`` behavior, request-list interface aside).

:meth:`ContinuousScheduler.run` drives the slot pool in either of two
loop modes.  *Closed loop* (the default) drains the queue as fast as
slots free — the historical behavior, bit for bit.  *Open loop*
(``arrivals_s=...``) gates admission on each request's arrival clock
and consults a pluggable :mod:`repro.serve.policy` admission policy per
tick, so queueing delay, burst backpressure, load shedding, and
SLO-adaptive accuracy-tier switching become first-class, measurable
behaviors (docs/serving.md §Admission policies).

Scope: decoder-only families.  Per-row position masking is exact for
attention caches; recurrent-state families (RG-LRU / SSD) integrate left
pads into their state, so admitting a padded prompt for them is rejected
(serve those with buckets equal to the true prompt length).
Encoder-decoder configs are rejected at construction.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.policy import AdmissionPolicy, LoadSnapshot, StaticTier, get_policy
from repro.serve.request import Request, RequestStats
from repro.serve.stats import ServeResult, ServeStats, SlotAccounting
from repro.serve.strategy import RowView, TierEngine, build_tier_engine, get_strategy
from repro.train.steps import make_decode_step, make_prefill_step

__all__ = [
    "ContinuousScheduler",
    "continuous_serve_loop",
    "static_serve_loop",
    "supports_continuous",
]

# Decode internals that used to live here as private closures/classes and
# now belong to repro.serve.strategy.  Importing them from this module was
# never supported API; raise with a pointer instead of silently breaking
# (docs/engine.md §Migration map has the closure -> strategy mapping).
_MOVED_TO_STRATEGY = {
    "_TierEngine": "TierEngine",
    "_build_engine": "build_tier_engine",
    "decode_greedy": "GreedyDecode.decode_round",
    "seat": "ContinuousScheduler.run (scheduler-internal)",
    "retire": "ContinuousScheduler.run (scheduler-internal)",
    "pump": "ContinuousScheduler.run (scheduler-internal)",
}


def __getattr__(name):
    if name in _MOVED_TO_STRATEGY:
        raise AttributeError(
            f"repro.serve.scheduler.{name} moved to the decode-strategy "
            f"layer: use repro.serve.strategy.{_MOVED_TO_STRATEGY[name]} "
            f"(see docs/engine.md, 'Scheduler closures -> DecodeStrategy')"
        )
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

RECURRENT_KINDS = ("rglru", "ssd")  # layer kinds with pad-absorbing state


def has_recurrent_state(cfg) -> bool:
    return any(k in RECURRENT_KINDS for k in cfg.layer_pattern)


def supports_continuous(cfg) -> bool:
    """Whether the continuous scheduler fully supports ``cfg`` — including
    padded admission of mixed-length prompts.  One predicate shared by the
    scheduler's own checks and the CLI's auto-selection, so they cannot
    drift: attention-only decoder stacks qualify; encoder-decoder configs
    are rejected at construction and recurrent-state families reject
    padded admission."""
    return not cfg.is_encdec and not has_recurrent_state(cfg)


def _apply_pool_quality(model, quality):
    """Resolve an accuracy tier into this pool's engine config: the
    ``engine.config`` controller picks each GEMM class's cheapest valid
    splitting point and the model is rebuilt on the resulting config
    (parameters are unaffected — approximation only changes the forward
    math).  Returns ``(model, canonical_tier_name)``."""
    if quality is None:
        return model, None
    from repro.engine import config as engine_config
    from repro.models.registry import build_model

    tier = engine_config.get_tier(quality)
    return build_model(engine_config.apply_quality(model.cfg, tier)), tier.name


def _check_request_quality(req: Request, pool_tier) -> None:
    """A request sold at a tier must be served by a pool resolved to that
    tier — mismatches raise at admission instead of silently serving the
    request at a different accuracy."""
    if req.quality is None:
        return
    from repro.engine.config import get_tier

    want = get_tier(req.quality).name
    if pool_tier is None:
        raise ValueError(
            f"request {req.id} demands quality tier {want!r}, but this pool "
            f"was built without one (pass quality={want!r}, or run one pool "
            f"per tier)"
        )
    if want != pool_tier:
        raise ValueError(
            f"request {req.id} demands quality tier {want!r}, but this pool "
            f"serves {pool_tier!r}; run one pool per tier"
        )


def _scatter_row(big: dict, small: dict, row) -> dict:
    """Write the single-row cache pytree ``small`` into row ``row`` of ``big``.

    Leaf layout follows ``transformer.init_caches``: ``scan`` leaves carry
    the batch on axis 1 (stacked layer groups first), ``rem`` leaves on
    axis 0.  Jitted with the pool cache donated, this is the admission
    primitive — one scatter, surviving rows untouched.
    """
    row = jnp.asarray(row, jnp.int32)

    def scat(axis):
        def f(b, s):
            starts = [jnp.int32(0)] * b.ndim
            starts[axis] = row
            return jax.lax.dynamic_update_slice(b, s.astype(b.dtype), tuple(starts))

        return f

    out = dict(big)
    if "scan" in big:
        out["scan"] = jax.tree_util.tree_map(scat(1), big["scan"], small["scan"])
    if "rem" in big:
        out["rem"] = jax.tree_util.tree_map(scat(0), big["rem"], small["rem"])
    return out


@dataclasses.dataclass
class _Slot:
    """Host-side state of one live row."""

    req: Request
    tokens: list  # generated token ids (first from admission prefill)
    admit_step: int
    t_first: float  # clock at first token (perf_counter closed loop)
    t_done: float = 0.0
    done: bool = False
    finish_reason: str = ""
    arrival_s: float = 0.0  # open loop: arrival time on the run clock
    queue_delay_s: Optional[float] = None  # open loop: admission - arrival
    tier_served: str = ""  # accuracy tier at admission ("" = pool config)
    proposed: int = 0  # speculative: draft tokens proposed for this row
    accepted: int = 0  # speculative: draft tokens the verify step accepted

    @property
    def emitted(self) -> int:
        return len(self.tokens)

    def absorb(self, tok: int, now: Optional[float] = None) -> None:
        """Take one token; ``now`` stamps completion on the open-loop
        clock (closed loop keeps the legacy perf_counter stamp)."""
        self.tokens.append(tok)
        if self.req.eos_id is not None and tok == self.req.eos_id:
            self.done, self.finish_reason = True, "eos"
        elif self.emitted >= self.req.max_new:
            self.done, self.finish_reason = True, "budget"
        if self.done:
            self.t_done = time.perf_counter() if now is None else now


class ContinuousScheduler:
    """Slot-pool continuous-batching scheduler over one model + params.

    Args:
      model, params: a built decoder-only model and its parameters.
      batch_size: number of slots (the jitted decode batch).
      prompt_len: prompt bucket width; every prompt (<= prompt_len) is
        left-padded to it so admission prefill compiles once.
      max_new: per-slot generation capacity (request budgets must fit).
      mesh: optional device mesh (e.g. ``sharding.data_parallel_mesh()``)
        installed around every jitted call — the model's internal
        ``constrain`` rules then shard the pool batch over the data axis.
      quality: optional accuracy tier (a ``repro.engine.config`` tier
        name or ``QualityTier``).  The tier is resolved to a per-run
        engine config — the controller picks each GEMM class's cheapest
        splitting point meeting the tier's error budget — and the model
        is rebuilt on that config; the decode/prefill steps jit once
        against it.  Requests carrying a ``quality`` are checked against
        the pool's tier at admission: a mismatch raises rather than
        silently serving the request at a different accuracy.
      strategy: the pool's decode discipline — a
        :mod:`repro.serve.strategy` name (``"greedy"`` / ``"speculative"``)
        or a :class:`~repro.serve.strategy.DecodeStrategy` instance.
        ``GreedyDecode`` (the default) reproduces the pre-strategy
        scheduler bit for bit; ``SelfSpeculative`` reserves
        ``strategy.extra_capacity`` spare physical KV slots per row for
        its verify window, admits at its verify tier, and commits
        1..k+1 verify-quality tokens per round.
    """

    def __init__(self, model, params, *, batch_size: int, prompt_len: int,
                 max_new: int, mesh=None, quality=None, strategy=None):
        if model.cfg.is_encdec:
            raise ValueError(
                "ContinuousScheduler supports decoder-only families; "
                "serve encoder-decoder configs with static_serve_loop"
            )
        if batch_size < 1 or prompt_len < 1 or max_new < 1:
            raise ValueError("batch_size, prompt_len and max_new must be >= 1")
        model, self.quality = _apply_pool_quality(model, quality)
        # recurrent-state layers integrate left pads into their state
        # (positions cannot mask them out), so padded admission would be
        # silently wrong — enforced per request in _pad
        self._recurrent = has_recurrent_state(model.cfg)
        self.model, self.params = model, params
        self.batch_size, self.prompt_len, self.max_new = batch_size, prompt_len, max_new
        self.strategy = get_strategy(strategy)
        # physical per-row cache: the logical window plus whatever spare
        # tail the strategy needs (speculative verify writes up to k past
        # the last committed slot before rollback)
        self.capacity = prompt_len + max_new + self.strategy.extra_capacity
        self.mesh = mesh
        self._cache_dtype = jnp.dtype(model.cfg.dtype)
        self._engines: dict = {}
        self._base_engine = self._build_engine(model, self.quality, self.quality)
        self._engines[self.quality] = self._base_engine
        # the pool tier's steps under their historical names — warmup and
        # external callers target the base engine
        self._admit_step = self._base_engine.admit_step
        self._prefill_pool = self._base_engine.prefill_pool
        self._decode = self._base_engine.decode

    # ------------------------------------------------------------- engines
    def _build_engine(self, model, name, key) -> TierEngine:
        """Jit the (admit, pool-prefill, decode, verify) bundle for one
        tier — the heavy lifting lives in
        :func:`repro.serve.strategy.build_tier_engine`."""
        return build_tier_engine(
            model, self.capacity, name=name, key=key, scatter_row=_scatter_row,
        )

    def engine_for(self, tier) -> TierEngine:
        """The engine serving ``tier`` (None = the pool's base config),
        built and jitted on first visit, cached for the scheduler's
        lifetime.  Safe to apply to the already-tier-resolved pool model:
        ``engine.config.apply_quality`` replaces the approx config
        wholesale, so re-tiering is not cumulative.  Decode strategies
        call this to reach their draft/verify tiers."""
        key = tier if tier is not None else self.quality
        eng = self._engines.get(key)
        if eng is None:
            model, name = _apply_pool_quality(self.model, key)
            eng = self._build_engine(model, name, key)
            self._engines[key] = eng
        return eng

    # pre-strategy private name, kept for callers that grew around it
    _engine_for = engine_for

    # ------------------------------------------------------------- helpers
    def _mesh_ctx(self):
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.distributed.sharding import mesh_context

        return mesh_context(self.mesh)

    def _pad(self, req: Request) -> tuple:
        """Left-pad one prompt into the bucket; true position ids for pads < 0.

        Tier-tag enforcement moved to the admission paths in :meth:`run`
        (it is policy-dependent now: an SLO-adaptive policy treats the
        tag as a preference, not a contract)."""
        ln = req.prompt_len
        if ln > self.prompt_len:
            raise ValueError(
                f"request {req.id}: prompt length {ln} exceeds bucket {self.prompt_len}"
            )
        if req.max_new > self.max_new:
            raise ValueError(
                f"request {req.id}: budget {req.max_new} exceeds slot capacity {self.max_new}"
            )
        if self._recurrent and ln < self.prompt_len:
            raise ValueError(
                f"request {req.id}: prompt length {ln} < bucket {self.prompt_len}, "
                f"but {self.model.cfg.name} has recurrent-state layers that would "
                f"integrate the left pads (positions cannot mask recurrent state); "
                f"use a bucket equal to the prompt length, or pad prompts upstream"
            )
        toks = np.zeros((self.prompt_len,), np.int32)
        toks[self.prompt_len - ln:] = req.tokens
        pos = np.arange(self.prompt_len, dtype=np.int32) - (self.prompt_len - ln)
        return toks, pos

    def _prefill_row(self, req: Request, caches: dict, row: int, engine=None):
        """Fused admission: single-row prefill + scatter; returns (caches, tok0)."""
        eng = engine if engine is not None else self._base_engine
        toks, pos = self._pad(req)
        caches, tok0 = eng.admit_step(
            self.params, caches, jnp.asarray(toks[None]), jnp.asarray(pos[None]),
            jnp.int32(row),
        )
        return caches, int(np.asarray(tok0))

    def warmup(self) -> None:
        """Compile the pool prefill, the admission step, and the pool decode."""
        B = self.batch_size
        caches = self.model.init_caches(B, self.capacity, self._cache_dtype)
        with self._mesh_ctx():
            toks = jnp.zeros((B, self.prompt_len), jnp.int32)
            pos = jnp.broadcast_to(
                jnp.arange(self.prompt_len, dtype=jnp.int32)[None], toks.shape
            )
            caches, _ = self._prefill_pool(self.params, toks, pos)
            req = Request(id=-1, tokens=np.zeros(1, np.int32), max_new=1)
            caches, _ = self._prefill_row(req, caches, 0)
            zeros = jnp.zeros((B,), jnp.int32)
            nxt, caches = self._decode(
                self.params, caches, jnp.zeros((B, 1), jnp.int32), zeros, zeros,
            )
            jax.block_until_ready(nxt)
            self.strategy.warmup(self)

    # ----------------------------------------------------------------- run
    def run(
        self,
        requests: Sequence[Request],
        *,
        warmup: bool = True,
        arrivals_s: Optional[Sequence[float]] = None,
        policy=None,
        step_time_s: float = 0.01,
        clock: str = "virtual",
    ) -> ServeResult:
        """Serve ``requests`` to completion; returns stats + token streams.

        **Closed loop** (default, ``arrivals_s=None``): the queue is
        drained as fast as slots free up — the pre-policy behavior, bit
        for bit (the implicit :class:`~repro.serve.policy.StaticTier`
        admits everything at the pool's tier through the same jitted
        steps, and all timing keeps the legacy run-start semantics).

        **Open loop** (``arrivals_s`` given — one non-decreasing arrival
        time per request, seconds from run start): a request becomes
        admissible only once the clock passes its arrival time, so
        queueing delay and burst backpressure are *measured* instead of
        assumed away.  Per-request ``ttft_s``/``latency_s`` are re-based
        to arrival, and ``queue_delay_s`` separates out the waiting
        component.  ``clock`` selects the timebase:

        * ``"virtual"`` (default) — deterministic modeled time: every
          admission prefill and pool decode step advances the clock by
          ``step_time_s`` scaled by the serving tier's
          :func:`repro.engine.config.tier_cycle_factor` (the paper's
          gate-delay model: cheaper tiers take genuinely shorter
          virtual steps, exact = 1.0).  Identical traces replay
          identical timings, so queue delays, SLO attainment, and
          tier-switch sequences are reproducible and CI-gateable.
        * ``"wall"`` — real time; idle gaps are slept through.

        ``policy`` is an :class:`~repro.serve.policy.AdmissionPolicy`
        instance or registry name (``"static"``/``"slo-adaptive"``/
        ``"reject"``).  Once per scheduler tick the policy picks the
        serving tier — admissions *and* decode run at it, pool-wide,
        the software analogue of reconfiguring the multipliers'
        splitting point in place — and per queued request it decides
        admit vs shed.  Tier switches reuse the one KV cache
        (approximation never changes cache shapes); each newly visited
        tier jits its step functions on first use.
        """
        open_loop = arrivals_s is not None
        pol = get_policy(policy) if policy is not None else StaticTier()
        if open_loop:
            arrivals = [float(a) for a in arrivals_s]
            if len(arrivals) != len(requests):
                raise ValueError(
                    f"arrivals_s has {len(arrivals)} entries for "
                    f"{len(requests)} requests"
                )
            if any(b < a for a, b in zip(arrivals, arrivals[1:])):
                raise ValueError("arrivals_s must be non-decreasing")
            if step_time_s <= 0:
                raise ValueError(f"step_time_s must be > 0, got {step_time_s}")
            if clock not in ("virtual", "wall"):
                raise ValueError(
                    f"clock must be 'virtual' or 'wall', got {clock!r}"
                )
        if warmup:
            self.warmup()
        B, P = self.batch_size, self.prompt_len
        pending: collections.deque = collections.deque(
            zip(requests, arrivals) if open_loop else ()
        )
        queue: collections.deque = collections.deque(
            () if open_loop else requests
        )
        arrived_at: dict = {}  # id -> arrival time, while queued (open loop)
        slots: list[Optional[_Slot]] = [None] * B
        retired: list[RequestStats] = []
        rejected: list[RequestStats] = []
        outputs: dict = {}
        cur_tok = np.zeros((B, 1), np.int32)
        prefill_s = decode_s = 0.0
        step = 0
        busy_row_steps = 0
        # slot-accounting ledger (see stats.SlotAccounting): counted as the
        # loop runs, so the soak harness audits the scheduler itself rather
        # than re-deriving "what must have happened" from the retired list
        seated_total = 0
        pool_seats = 0
        admission_seats = 0
        max_live = 0
        seat_counts = [0] * B
        last_write = [0] * B  # per-slot last physical KV write index
        position_violations = 0
        spec_rounds = spec_proposed = spec_accepted = 0
        modeled_cost = 0.0  # sum of round costs in exact-decode-step units
        engine = self._base_engine
        # admissions (prefill) run at the strategy's admission tier — for
        # greedy that is the serving engine itself; a speculative strategy
        # pins it to its verify tier so the cache prefix is verify-quality
        admit_eng = self.engine_for(self.strategy.admission_key(engine.key))
        pol.begin(self.quality)
        now = 0.0  # open-loop clock (virtual seconds, or wall since t0)

        t0 = time.perf_counter()

        def pump() -> None:
            # open loop: requests whose arrival time has passed move from
            # the pending stream into the admissible queue
            while pending and pending[0][1] <= now + 1e-12:
                req, arr = pending.popleft()
                arrived_at[req.id] = arr
                queue.append(req)

        def snapshot() -> LoadSnapshot:
            head_wait = 0.0
            if open_loop and queue:
                head_wait = now - arrived_at[queue[0].id]
            return LoadSnapshot(
                now_s=now if open_loop else time.perf_counter() - t0,
                step=step,
                queue_depth=len(queue),
                pending=len(pending),
                live_rows=sum(1 for s in slots if s is not None),
                batch_size=B,
                head_wait_s=head_wait,
            )

        def retire(i: int) -> None:
            s = slots[i]
            if open_loop:
                rs = RequestStats(
                    id=s.req.id,
                    prompt_len=s.req.prompt_len,
                    tokens_out=s.emitted,
                    admit_step=s.admit_step,
                    # what the client experiences: both re-based to arrival
                    ttft_s=s.t_first - s.arrival_s,
                    latency_s=(s.t_done if s.done else now) - s.arrival_s,
                    finish_reason=s.finish_reason,
                    arrival_s=s.arrival_s,
                    queue_delay_s=s.queue_delay_s,
                    tier_served=s.tier_served,
                    slo_ttft_s=s.req.slo_ttft_s,
                    proposed=s.proposed,
                    accepted=s.accepted,
                )
            else:
                rs = RequestStats(
                    id=s.req.id,
                    prompt_len=s.req.prompt_len,
                    tokens_out=s.emitted,
                    admit_step=s.admit_step,
                    ttft_s=s.t_first - t0,
                    latency_s=(s.t_done or time.perf_counter()) - t0,
                    finish_reason=s.finish_reason,
                    tier_served=s.tier_served,
                    slo_ttft_s=s.req.slo_ttft_s,
                    proposed=s.proposed,
                    accepted=s.accepted,
                )
            retired.append(rs)
            outputs[s.req.id] = np.asarray(s.tokens, np.int32)
            slots[i] = None
            pol.observe(rs)

        def reject(req: Request) -> None:
            if open_loop:
                arr = arrived_at.pop(req.id)
                rs = RequestStats(
                    id=req.id, prompt_len=req.prompt_len, tokens_out=0,
                    admit_step=step, ttft_s=0.0, latency_s=now - arr,
                    finish_reason="rejected", arrival_s=arr,
                    queue_delay_s=now - arr, slo_ttft_s=req.slo_ttft_s,
                )
            else:
                rs = RequestStats(
                    id=req.id, prompt_len=req.prompt_len, tokens_out=0,
                    admit_step=step, ttft_s=0.0,
                    latency_s=time.perf_counter() - t0,
                    finish_reason="rejected", slo_ttft_s=req.slo_ttft_s,
                )
            rejected.append(rs)

        def seat(i: int, req: Request, tok0: int, t_first: float,
                 *, pool: bool = False, arrival: float = 0.0,
                 queue_delay: Optional[float] = None) -> None:
            nonlocal seated_total, pool_seats, admission_seats
            seated_total += 1
            seat_counts[i] += 1
            if pool:
                pool_seats += 1
            else:
                admission_seats += 1
            # admission prefill wrote cache indices [0, P); the row's first
            # decode write lands at exactly P
            last_write[i] = P - 1
            slot = _Slot(req=req, tokens=[], admit_step=step, t_first=t_first,
                         arrival_s=arrival, queue_delay_s=queue_delay,
                         tier_served=admit_eng.name or "")
            slot.absorb(tok0, now=t_first if open_loop else None)
            cur_tok[i, 0] = tok0
            slots[i] = slot
            if slot.done:  # budget 1 / instant EOS: free the slot again
                retire(i)

        with self._mesh_ctx():
            if open_loop:
                if clock == "wall":
                    now = time.perf_counter() - t0
                pump()
            if (
                not open_loop
                and len(queue) >= B
                # only when the policy cannot shed (admit is the base
                # always-True implementation) — a shedding policy must see
                # every request through the per-request admission path
                and type(pol).admit is AdmissionPolicy.admit
            ):
                # initial fill: the batched prefill of all B slots *is* the
                # pool cache — one dispatch, no scatters
                first = [queue.popleft() for _ in range(B)]
                if pol.enforces_tier_tags:
                    for r in first:
                        _check_request_quality(r, self.quality)
                padded = [self._pad(r) for r in first]
                toks = jnp.asarray(np.stack([t for t, _ in padded]))
                pos = jnp.asarray(np.stack([p for _, p in padded]))
                caches, tok0s = admit_eng.prefill_pool(self.params, toks, pos)
                tok0s = np.asarray(tok0s)
                t_b = time.perf_counter()
                prefill_s += t_b - t0
                for i, req in enumerate(first):
                    seat(i, req, int(tok0s[i]), t_b, pool=True)
            else:
                caches = self.model.init_caches(B, self.capacity, self._cache_dtype)
            while True:
                if open_loop:
                    if clock == "wall":
                        now = time.perf_counter() - t0
                    pump()
                # one control tick: the policy picks this tick's serving
                # tier; admissions and decode below both run at it
                want = pol.tier(snapshot())
                want = want if want is not None else self.quality
                if want != engine.key:
                    engine = self.engine_for(want)
                    admit_eng = self.engine_for(
                        self.strategy.admission_key(engine.key))
                # retire finished rows, refill freed slots from the queue
                for i in range(B):
                    if slots[i] is not None and slots[i].done:
                        retire(i)
                    while slots[i] is None and queue:
                        req = queue[0]
                        if not pol.admit(req, snapshot()):
                            queue.popleft()
                            reject(req)
                            continue
                        queue.popleft()
                        if pol.enforces_tier_tags:
                            _check_request_quality(req, self.quality)
                        t_a = time.perf_counter()
                        caches, tok0 = self._prefill_row(req, caches, i, admit_eng)
                        t_b = time.perf_counter()
                        prefill_s += t_b - t_a
                        if open_loop:
                            arr = arrived_at.pop(req.id)
                            qd = now - arr
                            now = (
                                now + step_time_s * admit_eng.cost_factor
                                if clock == "virtual"
                                else time.perf_counter() - t0
                            )
                            seat(i, req, tok0, now, arrival=arr, queue_delay=qd)
                            pump()  # admission took time: new arrivals?
                        else:
                            seat(i, req, tok0, t_b)

                live = [i for i in range(B) if slots[i] is not None]
                if not live:
                    if open_loop and pending:
                        # idle gap: nothing decoding, nothing admissible —
                        # jump (or sleep) the clock to the next arrival
                        nxt_arrival = pending[0][1]
                        if clock == "virtual":
                            now = max(now, nxt_arrival)
                        else:
                            wait = nxt_arrival - (time.perf_counter() - t0)
                            if wait > 0:
                                time.sleep(wait)
                            now = time.perf_counter() - t0
                        pump()
                        continue
                    break
                max_live = max(max_live, len(live))

                # one decode round, delegated to the pool's strategy: greedy
                # is exactly the historical single decode; speculative is k
                # draft steps + one batched verify forward
                rows = [
                    RowView(index=i, prompt_len=slots[i].req.prompt_len,
                            emitted=slots[i].emitted,
                            strategy=slots[i].req.strategy)
                    for i in live
                ]
                t_d = time.perf_counter()
                rr = self.strategy.decode_round(
                    self, engine, caches, cur_tok, rows,
                    speculate=pol.speculation(snapshot()),
                )
                caches = rr.caches
                decode_s += time.perf_counter() - t_d
                step += rr.steps
                busy_row_steps += len(live) * rr.steps
                modeled_cost += rr.cost
                spec_proposed += rr.proposed
                spec_accepted += rr.accepted
                if rr.proposed:
                    spec_rounds += 1
                if open_loop:
                    now = (
                        now + step_time_s * rr.cost
                        if clock == "virtual"
                        else time.perf_counter() - t0
                    )
                for i in live:
                    s = slots[i]
                    pr = rr.per_row.get(i)
                    if pr is not None:
                        s.proposed += pr[0]
                        s.accepted += pr[1]
                    for tok in rr.tokens.get(i, ()):
                        if s.done:  # budget/EOS cut the committed run short
                            break
                        # per committed token the same invariants the
                        # pre-strategy loop checked per step: the physical
                        # write index advances by exactly one slot, stays
                        # inside the logical window, and the true position
                        # is the write index shifted by the row's pad offset
                        wr = P + s.emitted - 1
                        pp = s.req.prompt_len + s.emitted - 1
                        if (
                            wr != last_write[i] + 1
                            or wr >= P + self.max_new
                            or pp != wr - (P - s.req.prompt_len)
                        ):
                            position_violations += 1
                        last_write[i] = wr
                        s.absorb(int(tok), now=now if open_loop else None)
                    cur_tok[i, 0] = s.tokens[-1]
                if open_loop:
                    pump()

        wall = time.perf_counter() - t0
        # SLO attainment over every *offered* request carrying an SLO:
        # rejected (and any starved) requests count as missed, so a
        # shedding policy cannot game the metric by refusing work
        slo_total = sum(
            1 for r in requests if r.slo_ttft_s is not None
        )
        slo_attained = sum(
            1 for r in retired
            if r.slo_ttft_s is not None and r.ttft_s <= r.slo_ttft_s
        )
        switches = pol.switches
        stats = ServeStats(
            requests=len(retired),
            tokens_out=sum(r.tokens_out for r in retired),
            wall_s=wall,
            prefill_s=prefill_s,
            decode_s=decode_s,
            batch_latencies_s=(),
            devices=len(jax.devices()),
            scheduler="continuous",
            decode_steps=step,
            slot_utilization=busy_row_steps / (B * step) if step else 1.0,
            ttft_s=tuple(r.ttft_s for r in retired),
            request_latencies_s=tuple(r.latency_s for r in retired),
            quality=self.quality or "",
            open_loop=open_loop,
            policy=pol.name,
            queue_delay_s=tuple(
                r.queue_delay_s for r in retired
                if r.queue_delay_s is not None
            ),
            tier_switches=len(switches),
            rejected=len(rejected),
            starved=len(requests) - len(retired) - len(rejected),
            slo_total=slo_total,
            slo_attained=slo_attained,
            strategy=self.strategy.name,
            spec_rounds=spec_rounds,
            spec_proposed=spec_proposed,
            spec_accepted=spec_accepted,
            modeled_cost=modeled_cost,
        )
        accounting = SlotAccounting(
            seated=seated_total,
            retired=len(retired),
            pool_prefill_seats=pool_seats,
            admission_seats=admission_seats,
            max_live=max_live,
            slot_reuse=tuple(seat_counts),
            position_violations=position_violations,
        )
        return ServeResult(stats=stats, request_stats=tuple(retired),
                           outputs=outputs, accounting=accounting,
                           tier_switches=switches, rejected=tuple(rejected))


def continuous_serve_loop(
    model, params, requests: Sequence[Request], *,
    batch_size: int, prompt_len: int, max_new: int,
    mesh=None, warmup: bool = True, quality=None, strategy=None, **run_kwargs,
) -> ServeResult:
    """One-shot convenience wrapper over :class:`ContinuousScheduler`.

    ``strategy`` selects the pool's decode discipline (a
    :mod:`repro.serve.strategy` name or instance); ``run_kwargs`` pass
    through to :meth:`ContinuousScheduler.run` (``arrivals_s`` /
    ``policy`` / ``step_time_s`` / ``clock`` for open-loop clocked
    admission)."""
    sched = ContinuousScheduler(
        model, params,
        batch_size=batch_size, prompt_len=prompt_len, max_new=max_new, mesh=mesh,
        quality=quality, strategy=strategy,
    )
    return sched.run(requests, warmup=warmup, **run_kwargs)


# -------------------------------------------------------------------- static
@functools.lru_cache(maxsize=8)
def _static_steps(model, max_seq: int, mem_len: int):
    """Jitted (prefill, decode) pair per (model, shapes) — cached so
    repeated static runs (benchmark best-of repeats) reuse the compiles."""
    return (
        jax.jit(make_prefill_step(model, max_seq, mem_len=mem_len)),
        jax.jit(make_decode_step(model), donate_argnums=1),
    )


def static_serve_loop(
    model, params, requests: Sequence[Request], *,
    batch_size: int, prompt_len: int, gen: int,
    seed: int = 0, warmup: bool = True, quality=None,
) -> ServeResult:
    """The pre-continuous static-batch loop, kept as baseline and oracle.

    Pops ``batch_size`` requests at a time, left-pads prompts into the
    shared bucket (all rows share the ``arange`` position ids — the
    legacy position approximation), decodes every batch to the *largest*
    budget in it, and only re-batches once the whole batch drains.
    Finished rows burn dead decode steps until then; ``tokens_out``
    counts useful (budget/EOS-bounded) tokens only, so the throughput
    numbers are directly comparable with the continuous scheduler's.
    ``quality`` resolves an accuracy tier exactly as the continuous
    scheduler does, so per-tier parity holds bit for bit.
    """
    model, pool_tier = _apply_pool_quality(model, quality)
    cfg = model.cfg
    max_seq = prompt_len + gen
    mem_len = prompt_len if cfg.is_encdec else 0
    try:
        prefill, decode = _static_steps(model, max_seq, mem_len)
    except TypeError:  # unhashable model/config: build fresh, uncached
        prefill = jax.jit(make_prefill_step(model, max_seq, mem_len=mem_len))
        decode = jax.jit(make_decode_step(model), donate_argnums=1)
    rng = np.random.default_rng(seed)  # encoder-memory synthesis only

    def make_batch(batch_reqs: list) -> dict:
        b = len(batch_reqs)
        toks = np.zeros((b, prompt_len), np.int32)
        for i, r in enumerate(batch_reqs):
            _check_request_quality(r, pool_tier)
            if r.prompt_len > prompt_len:
                raise ValueError(
                    f"request {r.id}: prompt length {r.prompt_len} exceeds bucket {prompt_len}"
                )
            if r.max_new > gen:
                raise ValueError(
                    f"request {r.id}: budget {r.max_new} exceeds gen {gen}"
                )
            toks[i, prompt_len - r.prompt_len:] = r.tokens
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.is_encdec:
            batch["src_embeds"] = jnp.asarray(
                rng.standard_normal((b, prompt_len, cfg.d_model)), jnp.float32
            )
            batch["src_pos"] = jnp.arange(prompt_len, dtype=jnp.int32)[None].repeat(b, 0)
        return batch

    if warmup and requests:
        # compile every batch shape the loop will see: the full batch plus
        # the uneven remainder batch, so no XLA compile lands in the
        # timed region ("numbers measure scheduling, not compilation")
        shapes = {min(batch_size, len(requests))}
        if len(requests) > batch_size and len(requests) % batch_size:
            shapes.add(len(requests) % batch_size)
        for b0 in sorted(shapes):
            dummy = [Request(id=-1, tokens=np.zeros(1, np.int32), max_new=1)] * b0
            caches, logits = prefill(params, make_batch(dummy))
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            logits, caches = decode(params, caches, tok, jnp.int32(prompt_len))
            jax.block_until_ready(logits)

    queue = collections.deque(requests)
    retired: list[RequestStats] = []
    outputs: dict = {}
    prefill_s = decode_s = 0.0
    batch_latencies: list[float] = []
    total_steps = 0
    busy_row_steps = 0
    total_row_steps = 0
    max_live = 0

    t0 = time.perf_counter()
    while queue:
        t_batch = time.perf_counter()
        batch_reqs = [queue.popleft() for _ in range(min(batch_size, len(queue)))]
        max_live = max(max_live, len(batch_reqs))
        caches, logits = prefill(params, make_batch(batch_reqs))
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter()
        prefill_s += t_prefill - t_batch

        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        step_toks = [tok]  # device-side; materialized once per batch, so the
        t_first = time.perf_counter()  # decode loop dispatches async (pre-PR behavior)
        steps = min(gen, max(r.max_new for r in batch_reqs))
        for g in range(steps - 1):
            logits, caches = decode(params, caches, tok, jnp.int32(prompt_len + g))
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            step_toks.append(tok)
        jax.block_until_ready(tok)
        decode_s += time.perf_counter() - t_first
        host_toks = np.concatenate([np.asarray(t) for t in step_toks], axis=1)
        streams = [list(map(int, row)) for row in host_toks]
        total_steps += steps - 1
        t_end = time.perf_counter()
        batch_latencies.append(t_end - t_batch)

        for r, stream in zip(batch_reqs, streams):
            useful, reason = [], "budget"
            for t in stream[: r.max_new]:
                useful.append(t)
                if r.eos_id is not None and t == r.eos_id:
                    reason = "eos"
                    break
            # row r is live at decode step g iff it still needs token g+1:
            # steps past its useful length are the static batch's dead steps
            busy_row_steps += len(useful) - 1
            total_row_steps += steps - 1
            retired.append(RequestStats(
                id=r.id, prompt_len=r.prompt_len, tokens_out=len(useful),
                admit_step=0, ttft_s=t_first - t0, latency_s=t_end - t0,
                finish_reason=reason,
            ))
            outputs[r.id] = np.asarray(useful, np.int32)

    wall = time.perf_counter() - t0
    stats = ServeStats(
        requests=len(retired),
        tokens_out=sum(r.tokens_out for r in retired),
        wall_s=wall,
        prefill_s=prefill_s,
        decode_s=decode_s,
        batch_latencies_s=tuple(batch_latencies),
        devices=len(jax.devices()),
        scheduler="static",
        decode_steps=total_steps,
        slot_utilization=(
            busy_row_steps / total_row_steps if total_row_steps else 1.0
        ),
        ttft_s=tuple(r.ttft_s for r in retired),
        request_latencies_s=tuple(r.latency_s for r in retired),
        quality=pool_tier or "",
    )
    # the static loop has no slot pool: every request is seated by its
    # batch prefill and retired when the batch drains, so conservation is
    # structural — the ledger still reports it so soak audits run on both
    # schedulers with one code path
    accounting = SlotAccounting(
        seated=len(retired),
        retired=len(retired),
        pool_prefill_seats=len(retired),
        admission_seats=0,
        max_live=max_live,
        slot_reuse=(),
        position_violations=0,
    )
    return ServeResult(stats=stats, request_stats=tuple(retired),
                       outputs=outputs, accounting=accounting)
