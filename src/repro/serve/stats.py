"""Serve run measurements: aggregate :class:`ServeStats` + :class:`ServeResult`.

``ServeStats`` is the aggregate record both schedulers produce (the
``serve_throughput`` benchmark suite serializes it row-per-run); the
static fields are unchanged from the original ``launch.serve`` loop so
old readers keep working, and the continuous scheduler fills the per-
request distributions (TTFT, end-to-end latency) plus slot utilization.

``ServeResult`` bundles the stats with the per-request outcomes — the
greedy token streams (what the parity tests bit-compare) and one
:class:`~repro.serve.request.RequestStats` per retired request.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.serve.request import RequestStats

__all__ = ["ServeStats", "ServeResult", "SlotAccounting", "percentile", "fmt_ms"]


def percentile(values, q: float) -> Optional[float]:
    """float percentile of a sequence, or ``None`` when it is empty.

    ``None`` (not a sentinel 0.0, which reads as "instant") is the
    empty-distribution answer — callers that render must special-case it
    the way :func:`fmt_ms` does, and JSON rows carry ``null``.  A single
    sample is its own percentile at every ``q``.  ``q`` outside [0, 100]
    is a caller bug and raises here rather than deep inside numpy.
    """
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    vals = list(values)
    if not vals:
        return None
    return float(np.percentile(np.asarray(vals, np.float64), q))


def fmt_ms(values, q: float) -> str:
    """``percentile`` rendered as milliseconds — ``"n/a"`` for an empty
    distribution instead of a misleading ``0ms``."""
    p = percentile(values, q)
    if p is None:
        return "n/a"
    return f"{p * 1e3:.0f}ms"


@dataclasses.dataclass(frozen=True)
class SlotAccounting:
    """Slot-pool conservation ledger of one serve run.

    Counted live inside the scheduler loop (not reconstructed from the
    retired list), so the soak harness audits what actually happened:
    every request *seated* into a slot must eventually be *retired* from
    one (``slot_leaks == 0``), per-slot KV write positions must advance
    by exactly one physical slot per decode step and stay inside the
    cache (``position_violations == 0``), and ``slot_reuse`` records how
    many requests each physical slot hosted — its spread is the
    fragmentation indicator (one cold slot while others churn means the
    refill scan is skewing placement).
    """

    seated: int  # requests seated into a slot (pool prefill + admissions)
    retired: int  # requests retired out of a slot
    pool_prefill_seats: int  # seated by the initial batched prefill
    admission_seats: int  # seated by single-row admission prefills
    max_live: int  # peak live rows in any decode step
    slot_reuse: tuple  # per-slot seat counts, length batch_size ('()' for static)
    position_violations: int  # per-row write-slot monotonicity/bounds failures

    @property
    def slot_leaks(self) -> int:
        """Seated-but-never-retired rows after the run drained (must be 0)."""
        return self.seated - self.retired

    @property
    def reuse_spread(self) -> int:
        """max - min per-slot seat count: 0 = perfectly balanced reuse."""
        if not self.slot_reuse:
            return 0
        return int(max(self.slot_reuse) - min(self.slot_reuse))


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """What one serve run measured (all wall times in seconds)."""

    requests: int
    tokens_out: int  # useful tokens only (per-request budget/EOS-bounded)
    wall_s: float
    prefill_s: float  # total time in prefill (batched or per-admission)
    decode_s: float  # total time in the decode loops
    batch_latencies_s: tuple  # static scheduler: per-batch wall time; else ()
    devices: int
    scheduler: str = "static"  # "static" | "continuous"
    decode_steps: int = 0  # global decode steps executed
    slot_utilization: float = 1.0  # mean fraction of live rows per decode step
    ttft_s: tuple = ()  # per-request time-to-first-token
    request_latencies_s: tuple = ()  # per-request end-to-end latency
    quality: str = ""  # accuracy tier the pool was resolved to ("" = none)
    # ---- open-loop clocked admission (all default-off for old readers)
    open_loop: bool = False  # arrival-clocked admission vs queue drain
    policy: str = ""  # admission policy name ("" = implicit static)
    queue_delay_s: tuple = ()  # open loop: per-request admission - arrival
    tier_switches: int = 0  # pool tier transitions the policy performed
    rejected: int = 0  # requests the policy shed (offered, never served)
    starved: int = 0  # offered but neither served nor shed (must be 0)
    slo_total: int = 0  # offered requests carrying a TTFT SLO
    slo_attained: int = 0  # of those, served with ttft <= slo
    # ---- decode strategy (repro.serve.strategy; default-off for old readers)
    strategy: str = ""  # pool decode strategy ("" = pre-strategy record)
    spec_rounds: int = 0  # decode rounds that actually speculated
    spec_proposed: int = 0  # draft tokens proposed across those rounds
    spec_accepted: int = 0  # of those, accepted by the verify forward
    modeled_cost: float = 0.0  # sum of round costs in exact-step units

    @property
    def spec_rolled_back(self) -> int:
        """Draft tokens proposed but rejected: their KV writes were
        abandoned on the host side (the rollback counter)."""
        return self.spec_proposed - self.spec_accepted

    @property
    def accept_rate(self) -> Optional[float]:
        """Draft-token acceptance over the run, ``None`` when nothing was
        proposed — same no-data-is-not-zero convention as
        :func:`percentile` (a greedy run renders ``accept n/a``, not a
        fake 0%)."""
        if self.spec_proposed == 0:
            return None
        return self.spec_accepted / self.spec_proposed

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def requests_per_s(self) -> float:
        return self.requests / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def slo_attainment(self) -> Optional[float]:
        """Fraction of SLO-carrying *offered* requests served within SLO.

        Rejected/starved SLO requests count against the denominator (a
        shedding policy cannot improve this by refusing work); ``None``
        when no offered request carried an SLO — the same
        no-data-is-not-zero convention as :func:`percentile`.
        """
        if self.slo_total == 0:
            return None
        return self.slo_attained / self.slo_total

    def summary(self) -> str:
        extra = ""
        if self.scheduler == "continuous":
            extra = (
                f", {self.slot_utilization:.0%} slot util, "
                f"ttft p50 {fmt_ms(self.ttft_s, 50)}"
            )
        if self.open_loop:
            # ttft above is arrival-based in open loop; queue delay is its
            # waiting component — both keep the n/a-on-empty guard
            extra += f", queue p50 {fmt_ms(self.queue_delay_s, 50)}"
            att = self.slo_attainment
            extra += f", slo {att:.0%}" if att is not None else ""
            if self.rejected:
                extra += f", {self.rejected} rejected"
            if self.tier_switches:
                extra += f", {self.tier_switches} tier switches"
        if self.strategy and self.strategy != "greedy":
            # closed- and open-loop reports render the same acceptance
            # cell, with the empty-distribution n/a guard: a speculative
            # pool whose rounds never speculated says so instead of 0%
            ar = self.accept_rate
            if ar is None:
                extra += ", accept n/a"
            else:
                extra += (
                    f", accept {ar:.0%} "
                    f"({self.spec_rolled_back} rolled back)"
                )
        pol = f" [{self.policy}]" if self.policy and self.open_loop else ""
        tier = f" [tier {self.quality}]" if self.quality else ""
        strat = (
            f" [{self.strategy}]"
            if self.strategy and self.strategy != "greedy" else ""
        )
        return (
            f"[{self.scheduler}] served {self.requests} requests, "
            f"{self.tokens_out} tokens in {self.wall_s:.2f}s "
            f"({self.tokens_per_s:.1f} tok/s on {self.devices} device(s))"
            + extra + strat + pol + tier
        )


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """Stats + per-request outcomes of one serve run."""

    stats: ServeStats
    request_stats: tuple  # of RequestStats, retirement order
    outputs: dict  # request id -> np.ndarray int32 generated tokens
    accounting: Optional[SlotAccounting] = None  # slot ledger (both loops fill it)
    # of policy.TierSwitch, in order — the autoscaling event stream an
    # SLO-adaptive run produces (empty for static/closed-loop runs)
    tier_switches: tuple = ()
    # of RequestStats with finish_reason "rejected": offered requests the
    # admission policy shed.  Kept out of request_stats/outputs so parity
    # and audit consumers only ever see rows that actually decoded.
    rejected: tuple = ()

    def tokens_for(self, request_id: int) -> np.ndarray:
        return self.outputs[request_id]

    def stats_for(self, request_id: int) -> RequestStats:
        for rs in self.request_stats:
            if rs.id == request_id:
                return rs
        raise KeyError(f"request {request_id} was not served")
