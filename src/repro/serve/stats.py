"""Serve run measurements: aggregate :class:`ServeStats` + :class:`ServeResult`.

``ServeStats`` is the aggregate record both schedulers produce (the
``serve_throughput`` benchmark suite serializes it row-per-run); the
static fields are unchanged from the original ``launch.serve`` loop so
old readers keep working, and the continuous scheduler fills the per-
request distributions (TTFT, end-to-end latency) plus slot utilization.

``ServeResult`` bundles the stats with the per-request outcomes — the
greedy token streams (what the parity tests bit-compare) and one
:class:`~repro.serve.request.RequestStats` per retired request.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.request import RequestStats

__all__ = ["ServeStats", "ServeResult", "percentile", "fmt_ms"]


def percentile(values, q: float) -> float:
    """float percentile of a possibly-empty sequence (0.0 when empty)."""
    vals = list(values)
    if not vals:
        return 0.0
    return float(np.percentile(np.asarray(vals, np.float64), q))


def fmt_ms(values, q: float) -> str:
    """``percentile`` rendered as milliseconds — ``"n/a"`` for an empty
    distribution instead of a misleading ``0ms`` (the empty-input 0.0 of
    ``percentile`` is a sentinel, not a measurement)."""
    vals = list(values)
    if not vals:
        return "n/a"
    return f"{percentile(vals, q) * 1e3:.0f}ms"


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """What one serve run measured (all wall times in seconds)."""

    requests: int
    tokens_out: int  # useful tokens only (per-request budget/EOS-bounded)
    wall_s: float
    prefill_s: float  # total time in prefill (batched or per-admission)
    decode_s: float  # total time in the decode loops
    batch_latencies_s: tuple  # static scheduler: per-batch wall time; else ()
    devices: int
    scheduler: str = "static"  # "static" | "continuous"
    decode_steps: int = 0  # global decode steps executed
    slot_utilization: float = 1.0  # mean fraction of live rows per decode step
    ttft_s: tuple = ()  # per-request time-to-first-token
    request_latencies_s: tuple = ()  # per-request end-to-end latency
    quality: str = ""  # accuracy tier the pool was resolved to ("" = none)

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def requests_per_s(self) -> float:
        return self.requests / self.wall_s if self.wall_s > 0 else 0.0

    def summary(self) -> str:
        extra = ""
        if self.scheduler == "continuous":
            extra = (
                f", {self.slot_utilization:.0%} slot util, "
                f"ttft p50 {fmt_ms(self.ttft_s, 50)}"
            )
        tier = f" [tier {self.quality}]" if self.quality else ""
        return (
            f"[{self.scheduler}] served {self.requests} requests, "
            f"{self.tokens_out} tokens in {self.wall_s:.2f}s "
            f"({self.tokens_per_s:.1f} tok/s on {self.devices} device(s))"
            + extra + tier
        )


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """Stats + per-request outcomes of one serve run."""

    stats: ServeStats
    request_stats: tuple  # of RequestStats, retirement order
    outputs: dict  # request id -> np.ndarray int32 generated tokens

    def tokens_for(self, request_id: int) -> np.ndarray:
        return self.outputs[request_id]

    def stats_for(self, request_id: int) -> RequestStats:
        for rs in self.request_stats:
            if rs.id == request_id:
                return rs
        raise KeyError(f"request {request_id} was not served")
