"""Product / error lookup tables and their low-rank factorizations.

The TPU-native analogue of the paper's LUT-fabric FPGA deployment: for
n <= 8 the full 2^n x 2^n approximate-product table fits comfortably in
VMEM (256 KiB at n=8, int32), so an approximate GEMM can gather scalar
products instead of simulating the bit-serial datapath.

Beyond the paper, we factor the *error* table E = approx - exact with a
truncated SVD: E[a, b] ≈ Σ_r U[a, r] · V[b, r].  A dot-product against
per-operand embeddings turns the error correction into an MXU matmul
(see ``core.approx_matmul.lowrank_matmul``), trading bit-exactness for
systolic-array throughput; the retained error-energy fraction is part of
the report.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.core import seqmul

__all__ = [
    "product_lut",
    "error_lut",
    "svd_error_factors",
    "lut_stats",
]


@functools.lru_cache(maxsize=32)
def _tables(n: int, t: int, fix_to_1: bool) -> tuple[np.ndarray, np.ndarray]:
    if n > 10:
        raise ValueError(f"LUT for n={n} would be 2^{2 * n} entries; cap is n<=10")
    v = np.arange(1 << n, dtype=np.uint64)
    a = np.repeat(v, 1 << n)
    b = np.tile(v, 1 << n)
    import jax

    # LUTs are trace-time constants; the first construction may happen
    # under a scan/jit trace (ApproxDense inside a scanned layer group),
    # so force eager evaluation of the simulator call.
    with jax.ensure_compile_time_eval():
        w = seqmul.seq_mul_words(
            jnp.asarray(a, jnp.uint32), jnp.asarray(b, jnp.uint32), n=n, t=t, approx=True, fix_to_1=fix_to_1
        )
        w = jax.tree_util.tree_map(np.asarray, w)
    approx = seqmul.assemble_product_u64(w, n=n, t=t).reshape(1 << n, 1 << n)
    exact = (a * b).reshape(1 << n, 1 << n)
    return approx.astype(np.int64), (approx.astype(np.int64) - exact.astype(np.int64))


def product_lut(n: int, t: int, *, fix_to_1: bool = True) -> np.ndarray:
    """(2^n, 2^n) int32 table: LUT[a, b] = approx_product(a, b)."""
    return _tables(n, t, fix_to_1)[0].astype(np.int32)


def error_lut(n: int, t: int, *, fix_to_1: bool = True) -> np.ndarray:
    """(2^n, 2^n) int32 table: E[a, b] = approx(a,b) - a*b."""
    return _tables(n, t, fix_to_1)[1].astype(np.int32)


def svd_error_factors(
    n: int, t: int, rank: int, *, fix_to_1: bool = True
) -> tuple[np.ndarray, np.ndarray, float]:
    """Truncated-SVD factors of the error table.

    Returns (U, V, energy): U (2^n, rank) f32, V (2^n, rank) f32 with
    E ≈ U @ V.T, and the retained squared-Frobenius energy fraction.
    """
    e = _tables(n, t, fix_to_1)[1].astype(np.float64)
    u, s, vt = np.linalg.svd(e, full_matrices=False)
    rank = min(rank, s.size)
    total = float((s**2).sum()) or 1.0
    kept = float((s[:rank] ** 2).sum())
    scale = np.sqrt(s[:rank])
    return (
        (u[:, :rank] * scale).astype(np.float32),
        (vt[:rank].T * scale).astype(np.float32),
        kept / total,
    )


def lut_stats(n: int, t: int, *, fix_to_1: bool = True) -> dict:
    e = _tables(n, t, fix_to_1)[1]
    return {
        "nonzero_frac": float(np.count_nonzero(e) / e.size),
        "mean_abs": float(np.abs(e).mean()),
        "max_abs": int(np.abs(e).max()),
        "vmem_bytes_product_lut": int(4 * (1 << (2 * n))),
    }
