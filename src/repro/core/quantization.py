"""Integer quantization bridging real-valued tensors to the n-bit multiplier.

The paper's multiplier is *unsigned* n x n -> 2n bit.  Real network
tensors are signed, so we use sign-magnitude: quantize symmetrically to
signed integers in (-2^n, 2^n), multiply magnitudes through the
approximate unit, and re-apply the sign — exactly how the unsigned core
would be wrapped in a signed datapath.

``QuantParams`` carries per-tensor or per-channel scales; calibration is
absmax (deterministic, reproducible).  ``fake_quant`` is the straight-
through estimator used by approximate-aware training.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["QuantParams", "calibrate_absmax", "quantize", "dequantize", "fake_quant"]


class QuantParams(NamedTuple):
    scale: jax.Array  # f32, broadcastable to the tensor
    bits: int  # magnitude bit-width n (sign carried separately)


def calibrate_absmax(x: jax.Array, *, bits: int, axis=None) -> QuantParams:
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    qmax = (1 << bits) - 1
    scale = jnp.maximum(amax, 1e-12) / qmax
    return QuantParams(scale=scale.astype(jnp.float32), bits=bits)


def quantize(x: jax.Array, qp: QuantParams) -> tuple[jax.Array, jax.Array]:
    """Returns (magnitude uint32 in [0, 2^bits), sign int8 in {-1, 0, 1})."""
    qmax = (1 << qp.bits) - 1
    q = jnp.clip(jnp.round(x / qp.scale), -qmax, qmax)
    return jnp.abs(q).astype(jnp.uint32), jnp.sign(q).astype(jnp.int8)


def dequantize(mag: jax.Array, sign: jax.Array, qp: QuantParams) -> jax.Array:
    return mag.astype(jnp.float32) * sign.astype(jnp.float32) * qp.scale


@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


def _ste_fwd(x):
    return jnp.round(x), None


def _ste_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_fwd, _ste_bwd)


def fake_quant(x: jax.Array, *, bits: int, axis=None) -> jax.Array:
    """Straight-through fake quantization (QAT substrate)."""
    qp = calibrate_absmax(jax.lax.stop_gradient(x), bits=bits, axis=axis)
    qmax = (1 << bits) - 1
    q = jnp.clip(_ste_round(x / qp.scale), -qmax, qmax)
    return q * qp.scale
