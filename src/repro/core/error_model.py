"""Closed-form and probabilistic error models (paper Sections IV-B, V-A/B).

The exact metrics are #P-complete (paper Theorems 1–2), so the paper
proposes propagating the signal probabilities ρ(Ŝ_i^j), ρ(Ĉ_i^j) through
the DNF forms of Eqs. (12)/(13), keeping cofactors w.r.t. the a-bits and
deliberately disregarding Ŝ–Ĉ cross-correlations.  We implement two
fidelity levels:

* ``order=0`` — full independence: propagate per-bit marginals, but
  condition each cycle exactly on b_j (the shared AND input, whose
  correlation across bit positions is structural, not incidental).
* ``order=1`` — the paper's cofactor scheme: every carry is tracked
  jointly with the a-bit of the position it was produced at, and every
  accumulated-sum bit with the a-bit one position below (which, after the
  right shift, is precisely the ``ρ(·|{a_i} ∪ V)`` cofactor the paper's
  product expansion consumes).

Both return per-cycle carry-crossing probabilities (Eq. 9), an ER upper
estimate combining cycles under independence (truncated Eq. 10), the
MAE-event probability ρ(Ĉ_{t-1}^{n-2} ∧ ¬Ĉ_{t-1}^{n-1}), and a MED
estimate from the deferred-carry weight ledger.  Calibration against
exhaustive ground truth is in ``benchmarks/error_tables.py``.

Empirical note recorded in EXPERIMENTS.md: the closed-form Eq. (11)
matches, bit-exactly, the maximum-magnitude *negative* ED of the design
with fix-to-1 disabled (deferred carries land one position high after the
shift, each overshooting by its own weight; the worst-case accumulation
telescopes to 2^{n+t-1} - 2^{t+1}).  The positive side (final-cycle carry
dropped, no fix) reaches 2^{n+t-1} exactly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.engine.recurrence import validate_nt

__all__ = [
    "mae_closed_form",
    "max_ed_dropped_carry",
    "EstimatorReport",
    "estimate",
]


def mae_closed_form(n: int, t: int) -> int:
    """Eq. (11): MAE = 2^{n+t-1} - 2^{t+1}.

    Degenerate splits accepted by ``validate_nt`` sit outside the paper's
    1 <= t <= n-1, n >= 2 derivation and are defined explicitly: at n=1
    the single-cycle product never produces an LSP carry, so exact and
    approximate coincide and the maximum deferred-carry overshoot is 0
    (the raw formula would go negative).  n=2, t=1 evaluates to 0 through
    the formula itself — the one-cycle deferral window closes before the
    deferred carry could land high — and is cross-checked against
    exhaustive enumeration via ``core.boolean_ref`` in the tests.
    """
    validate_nt(n, t)
    if n == 1:
        return 0
    return (1 << (n + t - 1)) - (1 << (t + 1))


def max_ed_dropped_carry(n: int, t: int) -> int:
    """Worst positive ED (p̂ < p) when the final LSP carry is dropped
    and fix-to-1 is disabled: the carry's product weight 2^{t} * 2^{n-1}."""
    validate_nt(n, t)
    if n == 1:
        return 0
    return 1 << (n + t - 1)


@dataclasses.dataclass(frozen=True)
class EstimatorReport:
    n: int
    t: int
    order: int
    er_per_cycle: tuple  # Eq. (9) per accumulation j = 1..n-1
    er_msp: float  # P(any MSP-observable error), independence-combined
    p_ed_mae: float  # ρ(Ĉ_{t-1}^{n-2} ∧ ¬Ĉ_{t-1}^{n-1})
    p_fix: float  # ρ(Ĉ_{t-1}^{n-1}): fix-to-1 firing probability
    med_abs_est: float  # deferred-carry weight ledger estimate of mean |ED|


def _half_adder_chain(paug, pm, c_in0, t_boundary=None, c_boundary=0.0):
    """Ripple a probabilistic carry chain over positions 0..len-1.

    paug[i], pm[i]: P(augend/addend bit = 1), independent.
    Returns (psum[i], carry_into[i+1] list); at ``t_boundary`` the chain's
    incoming carry is replaced by ``c_boundary`` (the deferred D-FF value)
    while the native carry-out at t_boundary-1 is reported separately.
    A boundary at ``nbits`` means the whole chain is LSP (degenerate n=1
    split): the reported LSP carry-out is the final carry.  Boundaries
    beyond the chain used to silently report a 0.0 LSP carry-out; they
    are rejected now.
    """
    nbits = len(paug)
    if t_boundary is not None and t_boundary > nbits:
        raise ValueError(f"t_boundary={t_boundary} beyond the {nbits}-bit chain")
    psum = np.zeros(nbits)
    c = c_in0
    c_lsp_out = 0.0
    for i in range(nbits):
        if t_boundary is not None and i == t_boundary:
            c_lsp_out = c
            c = c_boundary
        g = paug[i] * pm[i]
        pp = paug[i] * (1 - pm[i]) + (1 - paug[i]) * pm[i]
        psum[i] = pp * (1 - c) + (1 - pp) * c
        c = g + pp * c
    if t_boundary == nbits:
        c_lsp_out = c
    return psum, c, c_lsp_out


def _estimate_order0(n, t, pa, pb):
    """Independence propagation, conditioned exactly on each b_j."""
    ps = np.zeros(n + 1)  # P(S_i = 1), i in [0, n]
    p_cff = 0.0
    er_cycles = []
    p_cff_hist = [0.0]
    for j in range(n):
        paug = ps[1:].copy()  # S >> 1; aug bit n-1 gets S_n
        paug = np.concatenate([paug, [0.0]])[:n]
        new_ps = np.zeros(n + 1)
        er_j = 0.0
        cff_j = 0.0
        for bj, w in ((1, pb[j]), (0, 1 - pb[j])):
            pm = pa * bj
            psum, c_msp_out, c_lsp_out = _half_adder_chain(
                paug, pm, 0.0, t_boundary=t, c_boundary=p_cff
            )
            new_ps[:n] += w * psum
            new_ps[n] += w * c_msp_out
            er_j += w * c_lsp_out
            cff_j += w * c_lsp_out
        ps = new_ps
        p_cff = cff_j
        if j > 0:
            er_cycles.append(er_j)
        p_cff_hist.append(p_cff)
    return er_cycles, p_cff_hist


def _estimate_order1(n, t, pa, pb):
    """Cofactor propagation w.r.t. a-bits (paper Section V-B scheme).

    State: ps_c[i, v] = P(S_i = 1 | a_{i-1} = v).  After the right shift,
    position i's augend is old S_{i+1}, whose tracked conditioning variable
    is a_i — exactly the cofactor ρ(Ŝ_{i+1}^{j-1} | {a_i}) used by the
    paper's product expansion.  Carries are rippled with their joint
    dependence on the a-bit one position below.
    """
    ps_c = np.zeros((n + 1, 2))  # P(S_i=1 | a_{i-1}=v); i=0 column unused
    p_cff = 0.0
    er_cycles = []
    p_cff_hist = [0.0]
    for j in range(n):
        new_ps = np.zeros((n + 1, 2))
        er_j = 0.0
        cff_j = 0.0
        for bj, w in ((1, pb[j]), (0, 1 - pb[j])):
            # carry into position i, conditioned on a_{i-1}: c_cond[v]
            c_cond = np.zeros(2)
            sum_cond_prev = np.zeros((n + 1, 2))  # P(sum_i | a_{i-1})
            c_out_lsp = 0.0
            for i in range(n):
                paug_c = ps_c[i + 1]  # P(aug_i=1 | a_i = v)
                if i == t:
                    # marginalize over a_{i-1}.  estimate() already
                    # enforces t >= 1 via validate_nt; the i > 0 guard is
                    # defensive for direct callers so a boundary at 0 can
                    # never read pa[-1] (the old silent wraparound).
                    c_out_lsp = (
                        pa[i - 1] * c_cond[1] + (1 - pa[i - 1]) * c_cond[0]
                        if i > 0
                        else c_cond[0]
                    )
                    c_cond = np.array([p_cff, p_cff])  # D-FF, decorrelated
                c_marg = (
                    pa[i - 1] * c_cond[1] + (1 - pa[i - 1]) * c_cond[0]
                    if i > 0
                    else c_cond[0]
                )
                # sum bit conditioned on a_{i-1} (carry keeps the correlation)
                pp_m = 0.0
                c_next = np.zeros(2)
                for va in (0, 1):
                    wa = pa[i] if va else 1 - pa[i]
                    pm = va * bj
                    g = paug_c[va] * pm
                    pp = paug_c[va] * (1 - pm) + (1 - paug_c[va]) * pm
                    pp_m += wa * pp
                    c_next[va] = g + pp * c_marg
                for v in (0, 1):
                    cv = c_cond[v] if i > 0 else c_cond[0]
                    sum_cond_prev[i, v] = pp_m * (1 - cv) + (1 - pp_m) * cv
                c_cond = c_next
            if t == n:  # degenerate n=1 split: the whole chain is LSP
                c_out_lsp = pa[n - 1] * c_cond[1] + (1 - pa[n - 1]) * c_cond[0]
            c_msp_out = pa[n - 1] * c_cond[1] + (1 - pa[n - 1]) * c_cond[0]
            sum_cond_prev[n, :] = c_msp_out
            new_ps += w * sum_cond_prev
            er_j += w * c_out_lsp
            cff_j += w * c_out_lsp
        ps_c = new_ps
        p_cff = cff_j
        if j > 0:
            er_cycles.append(er_j)
        p_cff_hist.append(p_cff)
    return er_cycles, p_cff_hist


def estimate(
    n: int,
    t: int,
    *,
    order: int = 1,
    pa: np.ndarray | None = None,
    pb: np.ndarray | None = None,
) -> EstimatorReport:
    """Probabilistic metric estimation.

    pa/pb: per-bit P(bit = 1) of the operands (length n); default 0.5
    (uniform inputs).  A measured input PDF maps to per-bit marginals —
    the estimator only consumes marginals, mirroring the paper.

    ``(n, t)`` is validated through the engine's ``validate_nt`` (the
    same gate the recurrence itself applies), and ``pa``/``pb`` must be
    length-n probability vectors — the estimator used to silently accept
    invalid shapes and wrap negative indices.
    """
    validate_nt(n, t)
    pa = np.full(n, 0.5) if pa is None else np.asarray(pa, float)
    pb = np.full(n, 0.5) if pb is None else np.asarray(pb, float)
    for name, p in (("pa", pa), ("pb", pb)):
        if p.shape != (n,):
            raise ValueError(f"{name} must have shape ({n},), got {p.shape}")
        if np.any(p < 0.0) or np.any(p > 1.0):
            raise ValueError(f"{name} entries must be probabilities in [0, 1]")
    if order == 0:
        er_cycles, cff = _estimate_order0(n, t, pa, pb)
    elif order == 1:
        er_cycles, cff = _estimate_order1(n, t, pa, pb)
    else:
        raise ValueError(f"order must be 0 or 1, got {order}")

    er_msp = 1.0 - float(np.prod([1 - e for e in er_cycles]))
    # cff[j+1] is ρ(Ĉ_{t-1}^{j}); MAE event: carry at cycle n-2, none at n-1.
    p_ed_mae = float(cff[n - 1] * (1 - cff[n]))
    p_fix = float(cff[n])
    # deferred-carry ledger: a carry crossing at cycle j is re-applied one
    # position high -> |ED| contribution 2^{t+j-1}; the final cycle's is
    # dropped (fix-to-1 aside) -> 2^{t+n-2} expected... we sum expectations.
    med = sum(er_cycles[j - 1] * float(2 ** (t + j - 1)) for j in range(1, n - 1))
    med += cff[n] * float(2 ** (t + n - 2))
    return EstimatorReport(
        n=n,
        t=t,
        order=order,
        er_per_cycle=tuple(er_cycles),
        er_msp=er_msp,
        p_ed_mae=p_ed_mae,
        p_fix=p_fix,
        med_abs_est=med,
    )
