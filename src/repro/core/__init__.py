"""Core contribution of the paper: the accuracy-configurable sequential
multiplier via segmented carry chains, its error metrics/models, and the
approximate-GEMM modes that carry it into the training/serving framework."""

from repro.core.approx_matmul import Mode, approx_matmul, approx_matmul_int, error_moments
from repro.core.error_metrics import ErrorReport, eval_pairs, exhaustive_eval, mc_eval
from repro.core.error_model import estimate, mae_closed_form, max_ed_dropped_carry
from repro.core.luts import error_lut, lut_stats, product_lut, svd_error_factors
from repro.core.quantization import QuantParams, calibrate_absmax, dequantize, fake_quant, quantize
from repro.core.seqmul import (
    MAX_N,
    ProductWords,
    assemble_product_u64,
    seq_mul_approx_u32,
    seq_mul_exact_u32,
    seq_mul_words,
)
