"""Literal boolean reference of the paper's multiplier recurrences.

This module transcribes the Ŝ_i^j / Ĉ_i^j equations of Section IV-A (and
the exact S_i^j / C_i^j of Section III-A) *verbatim*, bit by bit, with
numpy — no word-level shortcuts.  It is deliberately slow and serves as
the ground-truth oracle for ``core.seqmul`` and the Pallas kernels.

Bits are LSB-first: ``bits[..., i]`` is bit i.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "bits_from_int",
    "int_from_bits",
    "mul_exact_bits",
    "mul_approx_bits",
]


def bits_from_int(x, n: int) -> np.ndarray:
    x = np.asarray(x, np.uint64)
    i = np.arange(n, dtype=np.uint64)
    return ((x[..., None] >> i) & np.uint64(1)).astype(np.uint8)


def int_from_bits(bits: np.ndarray) -> np.ndarray:
    n = bits.shape[-1]
    w = np.uint64(1) << np.arange(n, dtype=np.uint64)
    return (bits.astype(np.uint64) * w).sum(axis=-1, dtype=np.uint64)


def _mul_bits(a_bits: np.ndarray, b_bits: np.ndarray, t: int | None, fix_to_1: bool):
    """Shared driver.  ``t=None`` selects the exact recurrence (III-A)."""
    n = a_bits.shape[-1]
    a = a_bits.astype(np.uint8)
    b = b_bits.astype(np.uint8)
    batch = a.shape[:-1]
    p = np.zeros(batch + (2 * n,), np.uint8)

    # S has n+1 bits (S_n is the registered adder carry-out).
    S = np.zeros(batch + (n + 1,), np.uint8)
    # j = 0: S_i^0 = a_i & b_0, no carries.
    for i in range(n):
        S[..., i] = a[..., i] & b[..., 0]
    c_prev_ff = np.zeros(batch, np.uint8)  # Ĉ_{t-1}^{j-1} held in the D-FF
    p[..., 0] = S[..., 0]  # p_r = S_0^r for r in [0, n-1)

    for j in range(1, n):
        S_new = np.zeros_like(S)
        C = np.zeros(batch + (n,), np.uint8)  # C_i^j, i in [0, n)
        c_ff_out = np.zeros(batch, np.uint8)
        for i in range(n):
            m = a[..., i] & b[..., j]
            aug = S[..., i + 1]  # S_{i+1}^{j-1}
            if i == 0:
                S_new[..., 0] = aug ^ m
                C[..., 0] = aug & m
            elif t is not None and i == t:
                # segmented: carry-in is last cycle's LSP carry-out (D-FF)
                S_new[..., i] = aug ^ m ^ c_prev_ff
                C[..., i] = ((aug ^ m) & c_prev_ff) | (aug & m)
            else:
                c_in = C[..., i - 1]
                S_new[..., i] = aug ^ c_in ^ m
                C[..., i] = ((aug ^ m) & c_in) | (aug & m)
            if t is not None and i == t - 1:
                # Ĉ_{t-1}^{j} -> D-FF.  It does NOT ripple into bit t within
                # this cycle (the i == t branch above consumes c_prev_ff).
                c_ff_out = C[..., t - 1]
        S_new[..., n] = C[..., n - 1]  # S_n^j = C_{n-1}^j
        S = S_new
        c_prev_ff = c_ff_out
        if j < n - 1:
            p[..., j] = S[..., 0]

    # p_r = S_{r-n+1}^{n-1} for r in [n-1, 2n-1]
    for r in range(n - 1, 2 * n):
        p[..., r] = S[..., r - n + 1]

    if t is not None and fix_to_1:
        hit = c_prev_ff.astype(bool)  # Ĉ_{t-1}^{n-1}
        p[..., : n + t] = np.where(hit[..., None], np.uint8(1), p[..., : n + t])
    return p


def mul_exact_bits(a_bits: np.ndarray, b_bits: np.ndarray) -> np.ndarray:
    """Exact sequential multiplication per Section III-A."""
    return _mul_bits(a_bits, b_bits, t=None, fix_to_1=False)


def mul_approx_bits(
    a_bits: np.ndarray, b_bits: np.ndarray, *, t: int, fix_to_1: bool = True
) -> np.ndarray:
    """Approximate multiplication per Section IV-A (segmented carry chain).

    Accepts the same degenerate n=1 split as ``engine.recurrence
    .validate_nt`` (t=1: single-cycle product, no carry to defer, exact
    and approximate coincide).
    """
    n = a_bits.shape[-1]
    if not (1 <= t <= max(1, n - 1)):
        raise ValueError(f"t={t} out of range for n={n}")
    return _mul_bits(a_bits, b_bits, t=t, fix_to_1=fix_to_1)
