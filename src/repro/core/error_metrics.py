"""Empirical error metrics of Section III-B (BER, ER, ED, MAE, MED, NMED, MRED).

Metrics are computed on host in exact integer arithmetic (numpy int64 /
uint64) from device-simulated products — float rounding would corrupt EDs
at n = 32.  Both exhaustive (paper: n <= 16) and Monte-Carlo (paper: 2^32
patterns for n = 32) drivers are provided, chunked so memory stays flat.

Two MED conventions are reported: the paper's Eq. (6) averages *signed*
EDs; NMED/MRED comparisons against [3] conventionally use |ED|.  We carry
both (``med_signed``, ``med_abs``) and derive NMED/MRED from ``med_abs``.
Note: Eq. (8) as printed normalizes every sample by the *global* max
product (which would make MRED == NMED); we implement the standard
per-sample ``|ED| / max(1, p(a,b))`` (cf. [3]) and record the deviation.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax.numpy as jnp
import numpy as np

from repro.core import seqmul

__all__ = ["ErrorReport", "exhaustive_eval", "mc_eval", "eval_pairs"]


@dataclasses.dataclass(frozen=True)
class ErrorReport:
    n: int
    t: int
    fix_to_1: bool
    samples: int
    exhaustive: bool
    er: float  # P(p != p̂)                        Eq. (3)
    mae: int  # max |ED|                           Eq. (5)
    max_ed_pos: int  # largest p - p̂ > 0 (undershoot of p̂)
    max_ed_neg: int  # most negative p - p̂ (overshoot of p̂)
    med_signed: float  # mean ED                   Eq. (6)
    med_abs: float  # mean |ED|
    nmed: float  # med_abs / max_ab p              Eq. (7)
    mred: float  # mean |ED| / max(1, p)           Eq. (8), per-sample denom
    ber: tuple  # per-output-bit error rate        Eq. (2), len 2n

    def summary(self) -> str:
        return (
            f"n={self.n} t={self.t} fix={int(self.fix_to_1)} "
            f"ER={self.er:.4f} MAE={self.mae} MED={self.med_abs:.2f} "
            f"NMED={self.nmed:.3e} MRED={self.mred:.3e}"
        )


class _Accum:
    def __init__(self, n: int):
        self.n = n
        self.count = 0
        self.err = 0
        self.sum_ed = 0
        self.sum_abs_ed = 0
        self.max_ed = 0
        self.min_ed = 0
        self.sum_red = 0.0
        self.bit_err = np.zeros(2 * n, np.int64)

    def add(self, a: np.ndarray, b: np.ndarray, phat: np.ndarray) -> None:
        # exact products at n = 32 reach (2^32-1)^2 > int64 max: keep the
        # products unsigned and derive the signed ED from the wraparound
        # difference (|ED| < 2^63, so the reinterpretation is exact).
        pu = a.astype(np.uint64) * b.astype(np.uint64)
        phu = phat.astype(np.uint64)
        ed = (pu - phu).astype(np.int64)
        self.count += ed.size
        self.err += int(np.count_nonzero(ed))
        self.sum_ed += int(ed.sum(dtype=object)) if ed.size else 0
        self.sum_abs_ed += int(np.abs(ed).sum(dtype=object)) if ed.size else 0
        self.max_ed = max(self.max_ed, int(ed.max(initial=0)))
        self.min_ed = min(self.min_ed, int(ed.min(initial=0)))
        denom = np.maximum(pu.astype(np.float64), 1.0)
        self.sum_red += float((np.abs(ed) / denom).sum())
        diff = np.bitwise_xor(pu, phu)
        for i in range(2 * self.n):
            self.bit_err[i] += int(np.count_nonzero((diff >> np.uint64(i)) & np.uint64(1)))

    def report(self, *, t: int, fix_to_1: bool, exhaustive: bool) -> ErrorReport:
        c = max(self.count, 1)
        max_p = (2**self.n - 1) ** 2
        return ErrorReport(
            n=self.n,
            t=t,
            fix_to_1=fix_to_1,
            samples=self.count,
            exhaustive=exhaustive,
            er=self.err / c,
            mae=max(abs(self.max_ed), abs(self.min_ed)),
            max_ed_pos=self.max_ed,
            max_ed_neg=self.min_ed,
            med_signed=self.sum_ed / c,
            med_abs=self.sum_abs_ed / c,
            nmed=(self.sum_abs_ed / c) / max_p,
            mred=self.sum_red / c,
            ber=tuple(self.bit_err / c),
        )


def _simulate(a: np.ndarray, b: np.ndarray, *, n: int, t: int, fix_to_1: bool) -> np.ndarray:
    w = seqmul.seq_mul_words(
        jnp.asarray(a, jnp.uint32), jnp.asarray(b, jnp.uint32), n=n, t=t, approx=True, fix_to_1=fix_to_1
    )
    return seqmul.assemble_product_u64(w, n=n, t=t)


def eval_pairs(
    a: np.ndarray, b: np.ndarray, *, n: int, t: int, fix_to_1: bool = True, exhaustive: bool = False
) -> ErrorReport:
    acc = _Accum(n)
    acc.add(a, b, _simulate(a, b, n=n, t=t, fix_to_1=fix_to_1))
    return acc.report(t=t, fix_to_1=fix_to_1, exhaustive=exhaustive)


def _exhaustive_chunks(n: int, chunk: int) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    total = 1 << (2 * n)
    idx = np.arange(min(chunk, total), dtype=np.uint64)
    for start in range(0, total, chunk):
        cur = idx[: min(chunk, total - start)] + np.uint64(start)
        yield (cur >> np.uint64(n)), (cur & np.uint64((1 << n) - 1))


def exhaustive_eval(
    n: int, t: int, *, fix_to_1: bool = True, chunk: int = 1 << 22
) -> ErrorReport:
    """Exhaustive metric evaluation over all 2^{2n} input pairs (n <= 14)."""
    if 2 * n > 28:
        raise ValueError(f"exhaustive over 2^{2 * n} pairs is infeasible here; use mc_eval")
    acc = _Accum(n)
    for a, b in _exhaustive_chunks(n, chunk):
        acc.add(a, b, _simulate(a, b, n=n, t=t, fix_to_1=fix_to_1))
    return acc.report(t=t, fix_to_1=fix_to_1, exhaustive=True)


def mc_eval(
    n: int,
    t: int,
    *,
    samples: int = 1 << 22,
    fix_to_1: bool = True,
    seed: int = 0,
    chunk: int = 1 << 22,
    pdf_a=None,
    pdf_b=None,
) -> ErrorReport:
    """Monte-Carlo metric estimation (paper Section V-C methodology).

    ``pdf_a``/``pdf_b`` optionally give a measured input PDF (length 2^n,
    paper Section IV-B MED definition); default is uniform.
    """
    rng = np.random.default_rng(seed)
    acc = _Accum(n)
    done = 0
    while done < samples:
        cur = min(chunk, samples - done)
        if pdf_a is None:
            a = rng.integers(0, 1 << n, size=cur, dtype=np.uint64)
        else:
            a = rng.choice(1 << n, size=cur, p=pdf_a).astype(np.uint64)
        if pdf_b is None:
            b = rng.integers(0, 1 << n, size=cur, dtype=np.uint64)
        else:
            b = rng.choice(1 << n, size=cur, p=pdf_b).astype(np.uint64)
        acc.add(a, b, _simulate(a, b, n=n, t=t, fix_to_1=fix_to_1))
        done += cur
    return acc.report(t=t, fix_to_1=fix_to_1, exhaustive=False)
