"""Approximate GEMM built on the segmented-carry-chain multiplier.

Reference (pure-jnp) implementations of every approximate-matmul mode the
framework exposes.  The Pallas kernels in ``repro.kernels`` are tiled,
VMEM-resident versions of these; tests assert allclose between the two.

Modes
-----
``exact``     plain matmul (the baseline the paper compares against).
``bitexact``  every scalar product is the paper's approximate multiplier,
              via the (2^n, 2^n) product LUT (n <= 8): the faithful
              semantics, gather-bound on TPU (VPU).
``lowrank``   exact matmul + rank-r SVD correction of the error table:
              C = A·B + Σ_k s E[|a|,|b|] ≈ A·B + einsum(sU[|a|], sV[|b|]) —
              both terms run on the MXU.  Beyond-paper optimization.
``inject``    exact matmul + moment-matched Gaussian error injection
              (mean/var calibrated from the error table, scaled by √K):
              O(1) overhead surrogate for 1000-node approximate-aware
              training.

All real-valued entry points quantize sign-magnitude via
``core.quantization`` and dequantize with the product of scales.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import luts, quantization

Mode = Literal["exact", "bitexact", "lowrank", "inject"]

__all__ = ["approx_matmul_int", "approx_matmul", "Mode", "error_moments"]


# NB: these caches must hold *concrete* arrays even when first populated
# inside a jit/scan trace (ApproxDense in a scanned layer group), hence
# ensure_compile_time_eval around the device conversion.


@functools.lru_cache(maxsize=16)
def _lut_dev(n: int, t: int, fix_to_1: bool):
    with jax.ensure_compile_time_eval():
        return jnp.asarray(luts.product_lut(n, t, fix_to_1=fix_to_1))


@functools.lru_cache(maxsize=16)
def _err_dev(n: int, t: int, fix_to_1: bool):
    with jax.ensure_compile_time_eval():
        return jnp.asarray(luts.error_lut(n, t, fix_to_1=fix_to_1))


@functools.lru_cache(maxsize=16)
def _svd_dev(n: int, t: int, rank: int, fix_to_1: bool):
    u, v, energy = luts.svd_error_factors(n, t, rank, fix_to_1=fix_to_1)
    with jax.ensure_compile_time_eval():
        return jnp.asarray(u), jnp.asarray(v), energy


@functools.lru_cache(maxsize=32)
def error_moments(
    n: int, t: int, fix_to_1: bool = True, dist: str = "gaussian"
) -> tuple[float, float]:
    """(mean, std) of the signed error table under an operand distribution.

    ``dist="uniform"`` is the paper's Fig. 2 setting.  ``dist="gaussian"``
    weights the table by the magnitude PDF of absmax-quantized Gaussian
    activations (|x| ~ folded normal, absmax ≈ 4σ): real activations
    concentrate at small magnitudes where carries rarely cross the split,
    so uniform moments overestimate the injected error by ~an order of
    magnitude (measured in benchmarks/gemm_modes.py).
    """
    e = luts.error_lut(n, t, fix_to_1=fix_to_1).astype(np.float64)
    if dist == "uniform":
        mean, var = float(e.mean()), float(e.var())
    elif dist == "gaussian":
        mags = np.arange(1 << n, dtype=np.float64)
        sigma = (2**n - 1) / 4.0  # absmax calibration: max |x| ~ 4 sigma
        p = np.exp(-0.5 * (mags / sigma) ** 2)
        p /= p.sum()
        w = np.outer(p, p)
        mean = float((w * e).sum())
        var = float((w * e * e).sum()) - mean * mean
    else:
        raise ValueError(f"dist must be 'uniform' or 'gaussian', got {dist!r}")
    # signed sign-magnitude operands: the error rides sign_a*sign_b, whose
    # expectation is 0 for symmetric activations/weights — the *signed*
    # per-product error has zero mean and second moment mean^2 + var
    # (validated empirically in benchmarks/gemm_modes.py).
    return 0.0, float(np.sqrt(max(var + mean * mean, 0.0)))


def approx_matmul_int(
    mag_a: jax.Array,
    sign_a: jax.Array,
    mag_b: jax.Array,
    sign_b: jax.Array,
    *,
    n: int,
    t: int,
    fix_to_1: bool = True,
) -> jax.Array:
    """Bit-exact signed approximate GEMM on integer sign-magnitude operands.

    mag_a (M, K) uint32, mag_b (K, N) uint32, signs int8.  Returns f32
    (M, N) — accumulations are float32, exact for n <= 8 and K <= 2^8
    (|sum| < 2^24); asserted in tests.
    """
    lut = _lut_dev(n, t, fix_to_1)
    idx = mag_a[:, :, None] * jnp.uint32(1 << n) + mag_b[None, :, :]
    prod = jnp.take(lut.reshape(-1), idx.astype(jnp.int32), axis=0)  # (M, K, N)
    signed = prod.astype(jnp.float32) * (
        sign_a.astype(jnp.float32)[:, :, None] * sign_b.astype(jnp.float32)[None, :, :]
    )
    return signed.sum(axis=1)


def _quantize_operands(x, w, n):
    qx = quantization.calibrate_absmax(jax.lax.stop_gradient(x), bits=n)
    qw = quantization.calibrate_absmax(jax.lax.stop_gradient(w), bits=n)
    mx, sx = quantization.quantize(x, qx)
    mw, sw = quantization.quantize(w, qw)
    return (mx, sx, qx), (mw, sw, qw)


def approx_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    n: int = 8,
    t: int = 4,
    fix_to_1: bool = True,
    mode: Mode = "bitexact",
    rank: int = 8,
    key: jax.Array | None = None,
) -> jax.Array:
    """Real-valued approximate GEMM: x (M, K) @ w (K, N) -> (M, N) f32."""
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    if mode == "exact":
        return x @ w

    (mx, sx, qx), (mw, sw, qw) = _quantize_operands(x, w, n)
    scale = qx.scale * qw.scale
    ax = mx.astype(jnp.float32) * sx.astype(jnp.float32)  # quantized ints, signed
    aw = mw.astype(jnp.float32) * sw.astype(jnp.float32)

    if mode == "bitexact":
        acc = approx_matmul_int(mx, sx, mw, sw, n=n, t=t, fix_to_1=fix_to_1)
        return acc * scale

    exact_int = ax @ aw
    if mode == "lowrank":
        u, v, _ = _svd_dev(n, t, rank, fix_to_1)
        ue = u[mx.astype(jnp.int32)] * sx.astype(jnp.float32)[..., None]  # (M, K, r)
        ve = v[mw.astype(jnp.int32)] * sw.astype(jnp.float32)[..., None]  # (K, N, r)
        corr = jnp.einsum("ikr,kjr->ij", ue, ve)
        return (exact_int + corr) * scale

    if mode == "inject":
        if key is None:
            raise ValueError("mode='inject' needs a PRNG key")
        mean, std = error_moments(n, t, fix_to_1)
        k_dim = x.shape[-1]
        noise = mean * k_dim + std * jnp.sqrt(jnp.float32(k_dim)) * jax.random.normal(
            key, exact_int.shape, jnp.float32
        )
        return (exact_int + noise) * scale

    raise ValueError(f"unknown mode {mode!r}")
