"""Compatibility shim over ``repro.engine`` (the old reference GEMM API).

The reference-mode implementations, the artifact caches and the mode
dispatch that used to live here moved to ``repro.engine`` (modes /
artifacts / dispatch) — one registry, one cache, one recurrence for the
whole stack.  ``approx_matmul`` pins ``backend="reference"`` so existing
callers and tests keep the pure-jnp semantics; new code should call
``repro.engine.matmul``, which also auto-selects the Pallas backend.
"""

from __future__ import annotations

from typing import Literal

import jax

from repro.engine import artifacts as _artifacts, dispatch as _dispatch, modes as _modes

Mode = Literal["exact", "bitexact", "lowrank", "inject", "fakequant"]

__all__ = ["approx_matmul_int", "approx_matmul", "Mode", "error_moments"]


def error_moments(
    n: int, t: int, fix_to_1: bool = True, dist: str = "gaussian"
) -> tuple[float, float]:
    """(mean, std) of the signed error table — see ``engine.artifacts``."""
    return _artifacts.error_moments(n, t, fix_to_1, dist)


def approx_matmul_int(
    mag_a: jax.Array,
    sign_a: jax.Array,
    mag_b: jax.Array,
    sign_b: jax.Array,
    *,
    n: int,
    t: int,
    fix_to_1: bool = True,
) -> jax.Array:
    """Bit-exact signed approximate GEMM on integer sign-magnitude operands."""
    return _modes.bitexact_gemm_int(
        mag_a, sign_a, mag_b, sign_b, n=n, t=t, fix_to_1=fix_to_1
    )


def approx_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    n: int = 8,
    t: int = 4,
    fix_to_1: bool = True,
    mode: Mode = "bitexact",
    rank: int = 8,
    key: jax.Array | None = None,
) -> jax.Array:
    """Real-valued approximate GEMM: x (M, K) @ w (K, N) -> (M, N) f32."""
    return _dispatch.matmul(
        x, w, n=n, t=t, fix_to_1=fix_to_1, mode=mode, rank=rank, key=key,
        backend="reference",
    )
