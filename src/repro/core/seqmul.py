"""Accuracy-configurable sequential multiplier via segmented carry chains.

Fast word-packed implementation of the paper's sequential shift-add
multiplier (Echavarria et al., 2021).  The n-cycle accumulate-and-shift
recurrence is carried out with the accumulator *already split* at the
splitting point ``t`` into an LSP word (t bits) and an MSP word
(n - t + 1 bits, including the adder carry-out S_n).  The exact and the
approximate multiplier are then the *same* recurrence, differing only in
whether the LSP carry-out is consumed immediately (exact: within-cycle
ripple across the split) or deferred by one clock cycle through the
D flip-flop (approximate: the paper's segmented carry chain).

Bit-exactness against the paper's boolean Ŝ/Ĉ recurrences is asserted in
``tests/test_seqmul.py`` (cross-check vs. ``core.boolean_ref``).

Supported bit-widths: 1 <= n <= 32 (every internal word then fits uint32;
final products are assembled on host in uint64).  This covers the paper's
exhaustive range (n <= 16) and its Monte-Carlo range (n = 32).

The recurrence body itself lives in ``repro.engine.recurrence`` — the
single copy shared with the Pallas kernel (`kernels.seqmul_kernel`).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.recurrence import MAX_N, pack_u32, seqmul_recurrence, validate_nt

__all__ = [
    "ProductWords",
    "seq_mul_words",
    "seq_mul_exact_u32",
    "seq_mul_approx_u32",
    "assemble_product_u64",
    "MAX_N",
]


class ProductWords(NamedTuple):
    """A 2n-bit product in split-word form (all uint32).

    The product value is::

        p = lo + 2**(n-1) * (s_lsp + 2**t * s_msp)

    where ``lo`` holds product bits [0, n-1) (the bits shifted out of the
    accumulator), ``s_lsp``/``s_msp`` hold the final accumulator
    S^{n-1} = product bits [n-1, 2n].  ``c_last`` is the LSP carry-out of
    the final accumulation, Ĉ_{t-1}^{n-1} (always 0 for the exact
    multiplier); it drives the fix-to-1 multiplexers.
    """

    lo: jax.Array
    s_lsp: jax.Array
    s_msp: jax.Array
    c_last: jax.Array


def seq_mul_words_impl(
    a: jax.Array,
    b: jax.Array,
    *,
    n: int,
    t: int,
    approx: bool,
    fix_to_1: bool = True,
) -> ProductWords:
    """Run the n-cycle sequential multiplication, vectorized elementwise.

    Args:
      a: multiplier, uint32, any shape, values in [0, 2**n).
      b: multiplicand, uint32, same shape as ``a``.
      n: operand bit-width.
      t: splitting point (LSP is t bits wide).  For ``approx=False`` the
        result is independent of ``t`` (the split add with an immediate
        carry is an exact add); we keep the parameter so exact/approx share
        one code path.
      approx: defer the LSP carry-out by one cycle (segmented carry chain).
      fix_to_1: on a final-cycle LSP carry-out, force product bits
        [0, n+t) to 1 (the paper's error-compensation multiplexers).
        Ignored for the exact multiplier.
    """
    validate_nt(n, t)
    a = jnp.asarray(a, jnp.uint32)
    b = jnp.asarray(b, jnp.uint32)
    lo, s_lsp, s_msp, c_last = seqmul_recurrence(
        a, b, n=n, t=t, approx=approx, fix_to_1=fix_to_1
    )
    return ProductWords(lo, s_lsp, s_msp, c_last)


seq_mul_words = jax.jit(
    seq_mul_words_impl, static_argnames=("n", "t", "approx", "fix_to_1")
)


def assemble_product_u64(words: ProductWords, *, n: int, t: int) -> np.ndarray:
    """Host-side assembly of the 2n-bit product into numpy uint64."""
    lo = np.asarray(words.lo, np.uint64)
    s = np.asarray(words.s_lsp, np.uint64) + (np.asarray(words.s_msp, np.uint64) << np.uint64(t))
    return lo + (s << np.uint64(n - 1))


def _packed(a, b, n, t, approx, fix_to_1):
    if 2 * n > 31:
        raise ValueError(f"packed u32 product needs 2n <= 31 bits, got n={n}; use seq_mul_words")
    w = seq_mul_words(a, b, n=n, t=t, approx=approx, fix_to_1=fix_to_1)
    return pack_u32(w.lo, w.s_lsp, w.s_msp, n=n, t=t)


def seq_mul_exact_u32(a: jax.Array, b: jax.Array, *, n: int) -> jax.Array:
    """Exact sequential product packed into a single uint32 (n <= 15)."""
    return _packed(a, b, n, max(1, n // 2), approx=False, fix_to_1=False)


def seq_mul_approx_u32(
    a: jax.Array, b: jax.Array, *, n: int, t: int, fix_to_1: bool = True
) -> jax.Array:
    """Approximate (segmented carry chain) product packed in uint32 (n <= 15)."""
    return _packed(a, b, n, t, approx=True, fix_to_1=fix_to_1)
