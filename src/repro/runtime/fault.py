"""Fault-tolerant training loop: checkpoint/restart, retry with backoff,
straggler detection, failure injection for tests.

The loop is deliberately host-side Python around a jitted step: that is
where production failures surface (XLA aborts, preempted workers raise
through the runtime, data feeds stall).  Recovery = restore the latest
complete checkpoint (possibly onto a *different* mesh — the checkpoint
manager re-shards) and replay from its step; the counter-based data
pipeline regenerates exactly the batches the failed run would have seen.

Straggler mitigation on a real fleet pairs this with the launcher's
slow-host eviction; here the monitor measures per-step wall time against
a running EMA and reports (and optionally calls back on) outliers —
the signal an orchestrator consumes to evict/replace a host.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

__all__ = ["StragglerMonitor", "FailureInjector", "run_loop", "LoopResult"]


class StragglerMonitor:
    """EMA-based step-time outlier detector."""

    def __init__(self, factor: float = 3.0, decay: float = 0.9, warmup: int = 3):
        self.factor = factor
        self.decay = decay
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.count = 0
        self.slow_steps: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.count += 1
        if self.ema is None:
            self.ema = dt
            return False
        slow = self.count > self.warmup and dt > self.factor * self.ema
        if slow:
            self.slow_steps.append((step, dt))
        else:  # don't pollute the EMA with outliers
            self.ema = self.decay * self.ema + (1 - self.decay) * dt
        return slow


class FailureInjector:
    """Deterministic failure schedule for integration tests."""

    def __init__(self, fail_at: tuple = ()):
        self.fail_at = set(fail_at)
        self.fired: set = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class LoopResult:
    state: Any
    metrics_history: list
    failures: int
    restarts: int
    slow_steps: list


def run_loop(
    state: Any,
    step_fn: Callable,
    batch_fn: Callable[[int], dict],
    *,
    total_steps: int,
    ckpt=None,
    checkpoint_every: int = 0,
    max_failures: int = 3,
    injector: Optional[FailureInjector] = None,
    monitor: Optional[StragglerMonitor] = None,
    log_every: int = 0,
    backoff_s: float = 0.0,
) -> LoopResult:
    """Run ``total_steps`` of ``step_fn`` with recovery.

    ``batch_fn(step)`` must be pure in ``step`` (counter-based pipeline).
    ``state.step`` (int32 scalar) is the authoritative position.
    """
    monitor = monitor or StragglerMonitor()
    history: list = []
    failures = restarts = 0

    if ckpt is not None and ckpt.latest_step() is not None:
        state, at = ckpt.restore(state)
        restarts += 1

    while int(state.step) < total_steps:
        step = int(state.step)
        try:
            if injector is not None:
                injector.maybe_fail(step)
            t0 = time.perf_counter()
            batch = batch_fn(step)
            state, metrics = step_fn(state, batch)
            if hasattr(metrics.get("loss", None), "block_until_ready"):
                metrics["loss"].block_until_ready()
            dt = time.perf_counter() - t0
            monitor.record(step, dt)
            history.append({k: float(v) for k, v in metrics.items()})
            if log_every and step % log_every == 0:
                print(f"step {step:6d} loss {history[-1]['loss']:.4f} ({dt*1e3:.1f} ms)")
            if ckpt is not None and checkpoint_every and (step + 1) % checkpoint_every == 0:
                ckpt.save(step + 1, state)
        except Exception as e:  # noqa: BLE001 — recovery boundary
            failures += 1
            if failures > max_failures:
                raise RuntimeError(f"exceeded max_failures={max_failures}") from e
            if backoff_s:
                time.sleep(backoff_s * failures)
            if ckpt is not None and ckpt.latest_step() is not None:
                state, at = ckpt.restore(state)
                print(f"recovered from step {at} after: {e}")
            else:
                print(f"retrying step {step} after: {e}")
            restarts += 1

    if ckpt is not None:
        ckpt.wait()
    return LoopResult(state, history, failures, restarts, monitor.slow_steps)
