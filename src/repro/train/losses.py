"""Vocab-chunked cross-entropy.

For vocab sizes up to 256k, materializing (B, S, V) f32 logits dominates
activation memory (train_4k × gemma: 4096·256000·4 B = 4 GiB *per
sequence*).  The loss is therefore computed in vocab chunks under a
``lax.scan``: a running (max, sumexp) pair implements a streaming
logsumexp, and the label logit is gathered from whichever chunk owns it.
Backward re-computes per-chunk logits (the scan is rematerialized), so
peak live logits are (B, S, V_chunk).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import DP, TP, constrain

__all__ = ["chunked_cross_entropy", "cross_entropy_dense"]

V_CHUNK = 8192


def _softcap(x, cap):
    return jnp.tanh(x / cap) * cap if cap else x


def cross_entropy_dense(logits: jax.Array, labels: jax.Array,
                        softcap: Optional[float] = None) -> jax.Array:
    """Reference: full-logits CE.  logits (..., V) f32, labels (...) int32."""
    logits = _softcap(logits.astype(jnp.float32), softcap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - lab).mean()


def chunked_cross_entropy(
    hidden: jax.Array,
    w: jax.Array,
    labels: jax.Array,
    *,
    softcap: Optional[float] = None,
    v_chunk: int = V_CHUNK,
) -> jax.Array:
    """Streaming CE.  hidden (B, S, D); w (D, V) head matrix; labels (B, S)."""
    b, s, d = hidden.shape
    v = w.shape[1]
    h2 = hidden.reshape(b * s, d).astype(jnp.float32)
    lab = labels.reshape(b * s)
    v_chunk = min(v_chunk, v)
    pad = (-v) % v_chunk
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
    nc = (v + pad) // v_chunk
    wc = jnp.moveaxis(w.reshape(d, nc, v_chunk), 1, 0)  # (nc, D, Vc)

    def chunk(carry, xs):
        m, sexp, lab_logit = carry
        wck, start = xs
        # TP sharding of the chunk's vocab axis: without the constraints the
        # partitioner replicates this dot over the model axis (16x redundant
        # CE compute + a giant scatter-add all-reduce in backward) — §Perf.
        wck = constrain(wck, None, TP)
        logits = _softcap(h2 @ wck.astype(jnp.float32), softcap)  # (N, Vc)
        logits = constrain(logits, DP, TP)
        if pad:  # mask the padded tail columns of the last chunk
            col = start + jnp.arange(v_chunk)
            logits = jnp.where(col[None, :] < v, logits, -jnp.inf)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        sexp = sexp * jnp.exp(m - m_new) + jnp.exp(logits - m_new[:, None]).sum(axis=-1)
        # label-logit extraction as a masked reduction over the (sharded)
        # vocab axis — take_along_axis would force an all-gather of logits
        loc = lab - start
        inside = (loc >= 0) & (loc < v_chunk)
        col = jnp.arange(v_chunk, dtype=jnp.int32)
        onehot = col[None, :] == loc[:, None]  # (N, Vc) bool, TP-sharded
        got = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        lab_logit = jnp.where(inside, got, lab_logit)
        return (m_new, sexp, lab_logit), None

    n = b * s
    init = (
        jnp.full((n,), -jnp.inf, jnp.float32),
        jnp.zeros((n,), jnp.float32),
        jnp.zeros((n,), jnp.float32),
    )
    starts = jnp.arange(nc, dtype=jnp.int32) * v_chunk
    # remat the chunk body: without this, scan-AD saves every chunk's
    # (N, Vc) logits for backward — i.e. the full (N, V) logits we are
    # chunking to avoid.  With it, backward recomputes one chunk at a time.
    (m, sexp, lab_logit), _ = jax.lax.scan(jax.checkpoint(chunk), init, (wc, starts))
    lse = m + jnp.log(sexp)
    return (lse - lab_logit).mean()
