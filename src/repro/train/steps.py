"""Train / serve step factories.

``make_train_step`` builds the jitted step: gradient-accumulation
microbatching (``lax.scan`` over microbatches with running grad mean),
optional int8 error-feedback gradient compression, AdamW (f32 or 8-bit
states), vocab-chunked CE, MoE aux loss.  Remat is already applied inside
the model's scanned layer groups per ``cfg.remat``.

``make_prefill_step`` / ``make_decode_step`` are the serving pair:
prefill writes the KV/recurrent caches at positions [0, S); decode
consumes one token at ``pos`` with the cache as carried state.  These are
exactly what the dry-run lowers for the ``prefill_*`` / ``decode_*`` /
``long_*`` shape cells.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.registry import Model
from repro.optim import adamw, compress
from repro.train.losses import chunked_cross_entropy

__all__ = [
    "TrainState",
    "init_train_state",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "loss_fn",
]

AUX_COEF = 0.01


class TrainState(NamedTuple):
    params: Any
    opt: adamw.OptState
    comp: Optional[compress.CompressState]
    rng: jax.Array
    step: jax.Array


def init_train_state(model: Model, tcfg: TrainConfig, key: jax.Array) -> TrainState:
    kp, kr = jax.random.split(key)
    params = model.init_params(kp)
    return TrainState(
        params=params,
        opt=adamw.init(params, tcfg),
        comp=compress.init_state(params) if tcfg.grad_compress_bits else None,
        rng=kr,
        step=jnp.zeros((), jnp.int32),
    )


def _positions(cfg: ModelConfig, batch: dict) -> jax.Array:
    tokens = batch["tokens"]
    b, s = tokens.shape
    pos = jnp.arange(s, dtype=jnp.int32)[None, :] * jnp.ones((b, 1), jnp.int32)
    if cfg.use_mrope:
        return jnp.broadcast_to(pos[None], (3, b, s))  # text-only stream: t=h=w
    return pos


def _head_matrix(params: dict, cfg: ModelConfig) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def loss_fn(params, batch: dict, rng, model: Model) -> tuple[jax.Array, dict]:
    cfg = model.cfg
    ctx = model.ctx(rng)
    kwargs = {}
    if cfg.is_encdec:
        kwargs["src_embeds"] = batch["src_embeds"]
        se = batch["src_embeds"]
        kwargs["src_pos"] = jnp.arange(se.shape[1], dtype=jnp.int32)[None, :] * jnp.ones(
            (se.shape[0], 1), jnp.int32
        )
    elif cfg.frontend and "embeds" in batch:
        kwargs["embeds"] = batch["embeds"]
    hidden, _, aux = model.forward(
        params, batch.get("tokens"), _positions(cfg, batch), ctx, **kwargs
    )
    ce = chunked_cross_entropy(
        hidden, _head_matrix(params, cfg), batch["labels"], softcap=cfg.final_logit_softcap
    )
    loss = ce + AUX_COEF * aux
    return loss, {"loss": ce, "aux": aux}


def make_train_step(model: Model, tcfg: TrainConfig):
    """Returns ``train_step(state, batch) -> (state, metrics)`` (jit-ready)."""
    cfg = model.cfg
    accum = max(1, tcfg.grad_accum)

    def step_fn(state: TrainState, batch: dict):
        rng = jax.random.fold_in(state.rng, state.step)
        grad_of = jax.value_and_grad(loss_fn, has_aux=True)

        if accum == 1:
            (loss, parts), grads = grad_of(state.params, batch, rng, model)
        else:
            def micro(b):
                return jax.tree_util.tree_map(
                    lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]), b
                )

            mb = micro(batch)

            def body(carry, xb):
                g_acc, l_acc = carry
                (l, _), g = grad_of(state.params, xb, rng, model)
                g_acc = jax.tree_util.tree_map(lambda a, b_: a + b_, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (grads, loss), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), mb)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum
            parts = {"loss": loss, "aux": jnp.float32(0.0)}

        new_comp = state.comp
        cmetrics: dict = {}
        if state.comp is not None:
            grads, new_comp, cmetrics = compress.compress_grads(grads, state.comp)

        new_params, new_opt, ometrics = adamw.update(grads, state.opt, state.params, tcfg)
        metrics = {"loss": loss, **parts, **ometrics, **cmetrics}
        new_state = TrainState(
            params=new_params,
            opt=new_opt,
            comp=new_comp,
            rng=state.rng,
            step=state.step + 1,
        )
        return new_state, metrics

    return step_fn


# ------------------------------------------------------------------ serving
def make_prefill_step(model: Model, max_seq: int, *, mem_len: int = 0):
    """prefill(params, batch) -> (caches, last_token_logits).

    ``batch["positions"]`` (optional, (B, S) int32) supplies per-row
    *true* position ids for left-padded prompts — pad slots carry
    negative ids and are masked out of the KV cache, so a short prompt
    padded to the bucket width attends (and is later attended to) at its
    real positions.  Without it, positions are the shared ``arange(S)``
    (every row full-length, the legacy static-batch behavior).
    """
    cfg = model.cfg
    cache_dtype = jnp.dtype(cfg.dtype)

    def prefill(params, batch: dict):
        tokens = batch["tokens"]
        b, s = tokens.shape
        ctx = model.ctx()
        caches = model.init_caches(b, max_seq, cache_dtype, mem_len=mem_len)
        if cfg.is_encdec:
            memory = model.encode(params, batch["src_embeds"], batch["src_pos"], ctx)
            ck, cv = model.precompute_cross(params, memory, ctx)
            caches = caches._replace(cross_k=ck.astype(cache_dtype), cross_v=cv.astype(cache_dtype))
        if "positions" in batch:
            pos = jnp.asarray(batch["positions"], jnp.int32)
            cache_pos = jnp.zeros((b,), jnp.int32)  # per-row path in attention
        else:
            pos = jnp.arange(s, dtype=jnp.int32)[None, :] * jnp.ones((b, 1), jnp.int32)
            cache_pos = jnp.int32(0)
        if cfg.use_mrope:
            pos = jnp.broadcast_to(pos[None], (3, b, s))
        hidden, caches, _ = model.forward(
            params, tokens, pos, ctx, caches=caches, cache_pos=cache_pos
        )
        logits = model.lm_head(params, hidden[:, -1:, :])
        return caches, logits

    return prefill


def make_decode_step(model: Model):
    """decode(params, caches, token (B,1), pos, write_pos=None) -> (logits, caches).

    ``pos`` is either a scalar (legacy: every row decodes at the same
    position, which doubles as the cache write slot) or a per-row ``(B,)``
    vector of *true* positions.  With a vector, ``write_pos`` (``(B,)``,
    default ``pos``) gives each row's physical cache write slot — for a
    row admitted into a continuous-batching slot with pad offset d, the
    true position p writes physical slot p + d.  Per-row positions are
    what let one decode step advance rows sitting at different depths.
    """
    cfg = model.cfg

    def decode(params, caches, token: jax.Array, pos: jax.Array, write_pos=None):
        b = token.shape[0]
        ctx = model.ctx()
        pos = jnp.asarray(pos, jnp.int32)
        if pos.ndim == 0:
            p = pos * jnp.ones((b, 1), jnp.int32)
            cache_pos = pos
        else:
            p = pos[:, None]
            cache_pos = pos if write_pos is None else jnp.asarray(write_pos, jnp.int32)
        if cfg.use_mrope:
            p = jnp.broadcast_to(p[None], (3, b, 1))
        hidden, new_caches, _ = model.forward(
            params, token, p, ctx, caches=caches, cache_pos=cache_pos
        )
        logits = model.lm_head(params, hidden)
        return logits, new_caches

    return decode
