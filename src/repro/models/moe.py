"""Mixture-of-Experts FFN: top-k routing, capacity-factor sort-based dispatch.

Dispatch is the sort-based (dropping) scheme: the (T, k) expert assignments
are flattened and sorted by expert id, each assignment gets its rank within
its expert's contiguous run, and ranks >= capacity are dropped.  Tokens are
scattered into an (E, C, d) buffer, the expert GEMMs run as 3-D einsums
with E sharded over the TP axis (expert parallelism — the token->expert
resharding induces the all-to-all), and results are combined back with the
router gates.  Memory is O(T·k·d + E·C·d), never O(T·E·C).

The router is kept exact (tiny and control-flow-critical — mirrors the
paper keeping the sequential multiplier's *controller* exact); expert GEMMs
route through the approximate multiplier when ``'moe' in approx.targets``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import (
    DP, FSDP, TP, ambient_mesh, constrain, mesh_axis_sizes, shard_map,
)
from repro.engine import dispatch as _engine, modes as _engine_modes
from repro.models import layers
from repro.models.layers import Ctx

__all__ = ["init_moe", "moe_ffn"]


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    scale_in = d**-0.5
    scale_out = f**-0.5

    def nrm(k, shape, s):
        return (jax.random.normal(k, shape, jnp.float32) * s).astype(dtype)

    return {
        "router": nrm(kr, (d, e), scale_in).astype(jnp.float32),
        "we1": nrm(k1, (e, d, f), scale_in),
        "we3": nrm(k3, (e, d, f), scale_in),
        "we2": nrm(k2, (e, f, d), scale_out),
    }


def _expert_gemm(x: jax.Array, w: jax.Array, ctx: Ctx) -> jax.Array:
    """(E, C, a) @ (E, a, b) -> (E, C, b), optionally approximated.

    A vmap of the engine's 2-D GEMM over experts, so mode semantics —
    quantization, straight-through gradients, PRNG handling — are owned
    by the registry, identical to the dense path (per-expert keys for
    stochastic modes).  fakequant/inject stay O(1)-overhead at scale;
    bitexact/lowrank are intended for small E.  The backend is pinned to
    "reference" (unlike dense's "auto") because pallas_call bodies don't
    batch under this vmap; a batched expert kernel is future work.
    """
    ap = ctx.cfg.approx
    if not ap.enabled or "moe" not in ap.targets:
        return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype))
    # per-target quality override (engine.config tiers); the backend stays
    # pinned to "reference" below regardless — pallas bodies don't batch
    # under this vmap
    ap = ap.for_target("moe")
    spec = _engine_modes.get_mode(ap.mode)

    def one(xe, we, ke=None):
        return _engine.matmul(
            xe.astype(jnp.float32), we.astype(jnp.float32),
            n=ap.n, t=ap.t, fix_to_1=ap.fix_to_1, mode=ap.mode, rank=ap.rank,
            key=ke, backend="reference",
        )

    if spec.needs_key:
        key = _engine_modes.resolve_key(ap.mode, ctx.next_key())
        keys = jax.random.split(key, x.shape[0])
        return jax.vmap(one)(x, w, keys).astype(x.dtype)
    return jax.vmap(one)(x, w).astype(x.dtype)


# --------------------------------------------------------------------------
# Sharded dispatch/combine (expert parallelism, §Perf iteration 2).
#
# The pjit-only path below scatters all T·k assignments into one global
# (E·C, d) buffer; at kimi-k2 scale (1M tokens, 384 experts) the SPMD
# partitioner replicates that scatter per device (~120 GB of HBM traffic
# and TB-scale collectives — measured in EXPERIMENTS.md §Perf).  The
# sharded path keeps dispatch *local*: each (pod, data) shard routes its
# own T_loc tokens with a local capacity into the (E_loc, C_loc, d) slice
# of the experts owned by its model shard; expert GEMMs run in pjit-auto
# (weights keep their FSDP sharding); the combine gathers per-model-shard
# partial outputs and psums them over the model axis — the standard EP
# collective, (T_loc, d) instead of (E·C, d).


def _moe_sharded(params, x2, ctx: Ctx, mesh, sizes) -> tuple[jax.Array, jax.Array]:
    cfg = ctx.cfg
    tokens, d = x2.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    dp_axes = tuple(a for a in ("pod", "data") if a in sizes)
    n_dp = 1
    for a in dp_axes:
        n_dp *= sizes[a]
    n_ep = sizes["model"]
    t_loc = tokens // n_dp
    e_loc = e // n_ep
    cap_loc = max(1, min(round(t_loc * k / e * cfg.capacity_factor), t_loc))
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def dispatch(x_loc, router):
        logits = x_loc.astype(jnp.float32) @ router  # (T_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(axis=-1, keepdims=True), 1e-9)

        me = jax.lax.pmean(probs.mean(axis=0), dp_axes)
        ce_loc = jnp.zeros((e,), jnp.float32).at[expert.reshape(-1)].add(1.0) / (t_loc * k)
        ce = jax.lax.pmean(ce_loc, dp_axes)
        aux = e * jnp.sum(me * ce)

        flat_e = expert.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        pos = jnp.arange(t_loc * k, dtype=jnp.int32) - jnp.searchsorted(
            sorted_e, sorted_e, side="left"
        ).astype(jnp.int32)
        keep = pos < cap_loc
        e0 = jax.lax.axis_index("model").astype(jnp.int32) * e_loc
        mine = keep & (sorted_e >= e0) & (sorted_e < e0 + e_loc)
        local_dest = jnp.where(
            mine, (sorted_e.astype(jnp.int32) - e0) * cap_loc + pos, e_loc * cap_loc
        )
        token_idx = (order // k).astype(jnp.int32)
        xs = x_loc[token_idx]
        buf = jnp.zeros((e_loc * cap_loc + 1, d), x_loc.dtype).at[local_dest].set(
            jnp.where(mine[:, None], xs, 0)
        )[: e_loc * cap_loc].reshape(e_loc, cap_loc, d)
        dest_g = jnp.where(keep, sorted_e.astype(jnp.int32) * cap_loc + pos, e * cap_loc)
        gate_keep = (gate.reshape(-1)[order] * keep).astype(jnp.float32)
        return buf, dest_g, token_idx, gate_keep, aux

    buf, dest_g, token_idx, gate_keep, aux = shard_map(
        dispatch,
        mesh=mesh,
        in_specs=(P(dp_spec, None), P()),
        out_specs=(P("model", dp_spec, None), P(dp_spec), P(dp_spec), P(dp_spec), P()),
    )(x2, params["router"])

    # ---- expert FFN in pjit-auto: weights keep their (TP, FSDP) sharding
    buf = constrain(buf, TP, DP, None)
    act = jax.nn.silu if cfg.ffn_activation == "silu" else (
        lambda v: jax.nn.gelu(v, approximate=True)
    )
    h = act(_expert_gemm(buf, params["we1"], ctx)) * _expert_gemm(buf, params["we3"], ctx)
    h = constrain(h, TP, DP, None)
    y = _expert_gemm(h, params["we2"], ctx)
    y = constrain(y, TP, DP, None)

    def combine(y_loc, dest, tok, gk):
        e0 = jax.lax.axis_index("model").astype(jnp.int32) * e_loc
        e_of = dest // cap_loc
        pos = dest % cap_loc
        mine = (e_of >= e0) & (e_of < e0 + e_loc) & (dest < e * cap_loc)
        local_row = jnp.clip((e_of - e0) * cap_loc + pos, 0, e_loc * cap_loc - 1)
        flat = y_loc.reshape(e_loc * cap_loc, d)
        rows = flat[local_row].astype(jnp.float32) * jnp.where(mine, gk, 0.0)[:, None]
        out = jnp.zeros((t_loc, d), jnp.float32).at[tok].add(rows)
        return jax.lax.psum(out, "model")

    out = shard_map(
        combine,
        mesh=mesh,
        in_specs=(P("model", dp_spec, None), P(dp_spec), P(dp_spec), P(dp_spec)),
        out_specs=P(dp_spec, None),
    )(y, dest_g, token_idx, gate_keep)
    return out, aux


def moe_ffn(params: dict, x: jax.Array, ctx: Ctx) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux load-balance loss scalar)."""
    cfg = ctx.cfg
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    tokens = b * s
    x2 = x.reshape(tokens, d)
    x2 = constrain(x2, DP, None)

    mesh = ambient_mesh()
    sizes = mesh_axis_sizes(mesh)
    n_dp = 1
    for a in ("pod", "data"):
        n_dp *= sizes.get(a, 1)
    if (
        sizes.get("model", 1) > 1
        and e % sizes["model"] == 0
        and tokens % n_dp == 0
        and tokens // n_dp >= k
    ):
        out, aux = _moe_sharded(params, x2, ctx, mesh, sizes)
        return out.reshape(b, s, d).astype(x.dtype), aux

    # ---- router (exact, f32)
    logits = x2.astype(jnp.float32) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(axis=-1, keepdims=True), 1e-9)

    # ---- aux loss (Switch-style load balance)
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[expert.reshape(-1)].add(1.0) / (tokens * k)
    aux = e * jnp.sum(me * ce)

    # ---- sort-based dispatch with capacity
    cap = int(max(1, round(tokens * k / e * cfg.capacity_factor)))
    cap = min(cap, tokens)
    flat_e = expert.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within the expert's contiguous run
    pos = jnp.arange(tokens * k, dtype=jnp.int32) - jnp.searchsorted(
        sorted_e, sorted_e, side="left"
    ).astype(jnp.int32)
    keep = pos < cap
    dest = jnp.where(keep, sorted_e.astype(jnp.int32) * cap + pos, e * cap)
    token_idx = (order // k).astype(jnp.int32)

    xs = x2[token_idx]  # (T*k, d)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(
        jnp.where(keep[:, None], xs, 0)
    )
    buf = buf[: e * cap].reshape(e, cap, d)
    buf = constrain(buf, TP, None, None)  # expert parallelism: all-to-all here

    # ---- expert FFN (gated)
    act = jax.nn.silu if cfg.ffn_activation == "silu" else (
        lambda v: jax.nn.gelu(v, approximate=True)
    )
    h = act(_expert_gemm(buf, params["we1"], ctx)) * _expert_gemm(buf, params["we3"], ctx)
    h = constrain(h, TP, None, None)
    y = _expert_gemm(h, params["we2"], ctx)  # (E, C, d)
    y = constrain(y, TP, None, None)

    # ---- combine
    y_flat = jnp.concatenate([y.reshape(e * cap, d), jnp.zeros((1, d), y.dtype)], axis=0)
    y_tok = y_flat[dest]  # (T*k, d); dropped rows read the zero row
    w_tok = (gate.reshape(-1)[order] * keep).astype(jnp.float32)[:, None]
    out = jnp.zeros((tokens, d), jnp.float32).at[token_idx].add(
        y_tok.astype(jnp.float32) * w_tok
    )
    out = constrain(out, DP, None)
    return out.reshape(b, s, d).astype(x.dtype), aux
