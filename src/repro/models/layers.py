"""Shared neural layers: norms, RoPE (+M-RoPE), dense (with the paper's
approximate-multiplier modes), gated MLPs.

All layers are pure functions over nested-dict parameter trees; no flax.
``Ctx`` threads trace-time context (approx config, PRNG for error
injection, decode position) through the stack without global state.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ApproxConfig, ModelConfig
from repro.distributed.sharding import DP, TP, constrain
from repro.engine import dispatch as _engine, modes as _engine_modes

__all__ = ["Ctx", "rms_norm", "rope", "mrope", "dense", "mlp", "init_dense", "init_mlp"]


@dataclasses.dataclass
class Ctx:
    """Trace-time call context (not a pytree; holds config + rng plumbing)."""

    cfg: ModelConfig
    rng: Optional[jax.Array] = None  # base key for error injection
    _counter: int = 0  # python-level unique id per dense call site
    aux_losses: list = dataclasses.field(default_factory=list)  # MoE balance terms

    def next_key(self) -> Optional[jax.Array]:
        self._counter += 1
        if self.rng is None:
            return None
        return jax.random.fold_in(self.rng, self._counter)

    def aux_loss(self) -> jax.Array:
        if not self.aux_losses:
            return jnp.float32(0.0)
        total = self.aux_losses[0]
        for a in self.aux_losses[1:]:
            total = total + a
        return total


# --------------------------------------------------------------------- init
def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype) -> jax.Array:
    return _normal(key, (d_in, d_out), dtype, d_in**-0.5)


def init_mlp(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": init_dense(k1, cfg.d_model, cfg.d_ff, dtype),
        "w3": init_dense(k3, cfg.d_model, cfg.d_ff, dtype),
        "w2": init_dense(k2, cfg.d_ff, cfg.d_model, dtype),
    }


# ------------------------------------------------------------------- layers
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    freqs = _rope_freqs(x.shape[-1], theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope(x: jax.Array, positions: jax.Array, theta: float, sections: tuple) -> jax.Array:
    """Qwen2-VL multimodal RoPE.  positions: (3, B, S) — t/h/w ids.

    The head_dim/2 frequency bands are partitioned into ``sections``;
    each band rotates with its own position stream.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(x.shape[-1], theta)  # (half,)
    sec_id = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half)
    pos = positions.astype(jnp.float32)  # (3, B, S)
    # ang[b, s, f] = pos[sec_id[f], b, s] * freqs[f]
    ang = jnp.take(pos, sec_id, axis=0)  # (half, B, S)
    ang = jnp.moveaxis(ang, 0, -1) * freqs  # (B, S, half)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------- approximate dense
def _approx_2d(x2: jax.Array, w: jax.Array, ap: ApproxConfig, key) -> jax.Array:
    """One engine call: the mode registry owns fakequant/inject/bitexact/
    lowrank semantics (including the straight-through gradient rule that
    used to be re-implemented here)."""
    return _engine.matmul(
        x2.astype(jnp.float32),
        w.astype(jnp.float32),
        n=ap.n,
        t=ap.t,
        fix_to_1=ap.fix_to_1,
        mode=ap.mode,
        rank=ap.rank,
        key=_engine_modes.resolve_key(ap.mode, key),
        backend=ap.backend,
    )


def dense(x: jax.Array, w: jax.Array, ctx: Ctx, kind: str = "mlp") -> jax.Array:
    """x: (..., d_in) @ w (d_in, d_out), optionally through the approximate
    multiplier (paper technique) when ``kind`` is targeted.  The effective
    (n, t, mode, backend) comes from ``approx.for_target(kind)``, so a
    quality tier's per-GEMM-class selections (engine.config) apply here
    without the call site knowing about tiers."""
    ap = ctx.cfg.approx
    if not ap.enabled or kind not in ap.targets:
        return jnp.dot(x, w.astype(x.dtype))
    ap = ap.for_target(kind)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = _approx_2d(x2, w, ap, ctx.next_key())
    return out.reshape(*lead, w.shape[-1]).astype(x.dtype)


def mlp(params: dict, x: jax.Array, ctx: Ctx) -> jax.Array:
    """Gated MLP: SwiGLU (silu) or GeGLU (gelu)."""
    act = jax.nn.silu if ctx.cfg.ffn_activation == "silu" else (
        lambda v: jax.nn.gelu(v, approximate=True)
    )
    h = act(dense(x, params["w1"], ctx, "mlp")) * dense(x, params["w3"], ctx, "mlp")
    h = constrain(h, DP, None, TP)
    return dense(h, params["w2"], ctx, "mlp")
