"""Mamba-2 SSD (state-space duality) mixer, chunked matmul formulation.

The selective state-space recurrence

    S_t = exp(dt_t * A) * S_{t-1} + dt_t * B_t x_t^T        (per head)
    y_t = C_t . S_t + D * x_t

is evaluated in the SSD "chunked" form (Dao & Gu, 2024): the sequence is
split into chunks of length L; within a chunk the output is an
attention-like quadratic matmul against a decay-masked Gram matrix
(MXU-friendly), and across chunks a *linear* recurrence over O(S/L)
chunk states is evaluated with a log-depth associative scan — which is
what makes the 500k-token shapes tractable.

Decode carries (conv state, SSM state (B, H, P, N)) and is O(1) per token.
In/out projections route through the approximate multiplier; the state
update stays exact (the accumulator, per DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import DP, TP, constrain
from repro.models import layers
from repro.models.layers import Ctx

__all__ = ["SSDCache", "init_ssd", "ssd_block", "init_ssd_cache"]


class SSDCache(NamedTuple):
    conv: jax.Array  # (B, conv_width - 1, d_conv_channels)
    state: jax.Array  # (B, H, P, N) f32


def _dims(cfg: ModelConfig):
    d_inner = cfg.d_inner or 2 * cfg.d_model
    heads = cfg.ssm_heads or d_inner // cfg.ssm_head_dim
    return d_inner, heads, cfg.ssm_head_dim, cfg.ssm_state


def init_ssd(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_inner, h, p, n = _dims(cfg)
    conv_ch = d_inner + 2 * n  # x, B, C all pass the causal conv
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * n + h  # z, x, B, C, dt
    return {
        "in_proj": layers.init_dense(ks[0], d, in_dim, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "ssm_a": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),  # A = -exp(.)
        "ssm_d": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01))).astype(jnp.float32),
        "out_proj": layers.init_dense(ks[3], d_inner, d, dtype),
    }


def init_ssd_cache(cfg: ModelConfig, batch: int, dtype) -> SSDCache:
    d_inner, h, p, n = _dims(cfg)
    return SSDCache(
        conv=jnp.zeros((batch, cfg.conv_width - 1, d_inner + 2 * n), dtype),
        state=jnp.zeros((batch, h, p, n), jnp.float32),
    )


def _segsum(z: jax.Array) -> jax.Array:
    """(..., L) -> (..., L, L) lower-tri cumulative sums: out[i,j] = sum_{j<k<=i} z_k."""
    l = z.shape[-1]
    cs = jnp.cumsum(z, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(xh, dt, a, b_in, c_in, chunk: int):
    """Chunked SSD.

    xh: (B, S, H, P); dt: (B, S, H) (post-softplus); a: (H,) (negative);
    b_in, c_in: (B, S, N).  Returns (y (B, S, H, P), final state (B, H, P, N)).
    """
    bsz, s, h, p = xh.shape
    n = b_in.shape[-1]
    l = min(chunk, s)
    pad = (-s) % l
    if pad:
        # zero-pad the tail: dt=0 makes padded steps identity on the state
        # (decay exp(0)=1, zero injection), so the final state is exact.
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
    s_pad = s + pad
    nc = s_pad // l

    xc = xh.reshape(bsz, nc, l, h, p)
    dtc = dt.reshape(bsz, nc, l, h)
    bc = b_in.reshape(bsz, nc, l, n)
    cc = c_in.reshape(bsz, nc, l, n)

    da = dtc * a[None, None, None, :]  # (B, C, L, H) log-decay increments
    da_cum = jnp.cumsum(da, axis=2)  # within-chunk cumulative
    da_total = da_cum[:, :, -1, :]  # (B, C, H)

    # ---- intra-chunk (quadratic, MXU): Y[i] = sum_{j<=i} C_i.B_j exp(seg) dt_j x_j
    seg = _segsum(jnp.moveaxis(da, 2, 3))  # (B, C, H, L, L)
    gram = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B, C, L, L)
    m = gram[:, :, None, :, :] * jnp.exp(seg)  # (B, C, H, L, L)
    y_intra = jnp.einsum("bchij,bcjh,bcjhp->bcihp", m, dtc, xc)

    # ---- chunk states: S_c = sum_j exp(da_total - da_cum_j) dt_j B_j x_j^T
    decay_state = jnp.exp(da_total[:, :, None, :] - da_cum)  # (B, C, L, H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", bc, decay_state * dtc, xc)

    # ---- inter-chunk linear recurrence over C (associative scan, log depth)
    decay_chunk = jnp.exp(da_total)  # (B, C, H)

    def comb(left, right):
        al, sl = left
        ar, sr = right
        return al * ar, sl * ar[..., None, None] + sr

    a_all, s_all = jax.lax.associative_scan(comb, (decay_chunk, states), axis=1)
    # state entering chunk c is s_all[c-1]
    s_prev = jnp.concatenate(
        [jnp.zeros_like(s_all[:, :1]), s_all[:, :-1]], axis=1
    )  # (B, C, H, P, N)

    # ---- inter-chunk output: y_off[i] = C_i . (exp(da_cum_i) S_prev)
    decay_out = jnp.exp(da_cum)  # (B, C, L, H)
    y_inter = jnp.einsum("bcln,bchpn,bclh->bclhp", cc, s_prev, decay_out)

    y = (y_intra + y_inter).reshape(bsz, s_pad, h, p)[:, :s]
    return y, s_all[:, -1]  # final state (B, H, P, N)


def ssd_block(
    params: dict,
    x: jax.Array,
    ctx: Ctx,
    cache: Optional[SSDCache] = None,
) -> tuple[jax.Array, Optional[SSDCache]]:
    """x: (B, S, d_model) -> (out, new_cache)."""
    cfg = ctx.cfg
    d_inner, h, p, n = _dims(cfg)
    bsz, s, _ = x.shape

    zxbcdt = layers.dense(x, params["in_proj"], ctx, "mlp")
    z, xr, b_in, c_in, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xr, b_in, c_in], axis=-1)

    # causal depthwise conv (shared with rglru implementation style)
    from repro.models.rglru import _causal_conv

    conv_cache = cache.conv if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"], params["conv_b"], conv_cache)
    conv_out = jax.nn.silu(conv_out)
    xr, b_in, c_in = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B, S, H)
    a = -jnp.exp(params["ssm_a"])  # (H,)
    xh = xr.astype(jnp.float32).reshape(bsz, s, h, p)
    xh = constrain(xh, DP, None, TP, None)

    if cache is not None and s == 1:
        # O(1) decode: S = exp(dt a) S + dt B x^T ; y = C.S
        da = jnp.exp(dt[:, 0, :] * a[None, :])  # (B, H)
        dbx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], b_in[:, 0].astype(jnp.float32), xh[:, 0])
        state = da[..., None, None] * cache.state + dbx
        y = jnp.einsum("bn,bhpn->bhp", c_in[:, 0].astype(jnp.float32), state)[:, None]
        y = y.reshape(bsz, 1, h, p)
    else:
        # prefill: a provided cache is assumed fresh (zero state) — the
        # chunked scan starts from S_0 = 0 and the final state is returned.
        y, state = _ssd_chunked(
            xh, dt, a, b_in.astype(jnp.float32), c_in.astype(jnp.float32), cfg.ssm_chunk
        )

    y = y + params["ssm_d"][None, None, :, None] * xh
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = constrain(y, DP, None, TP)
    out = layers.dense(y, params["out_proj"], ctx, "mlp")
    new_cache = SSDCache(new_conv, state) if cache is not None else None
    return out, new_cache
