"""Encoder–decoder backbone (Seamless-M4T family).

Encoder: non-causal self-attention stack over precomputed *frame
embeddings* (the modality frontend is a stub per the assignment — inputs
arrive as (B, S_src, d_model) conformer-frame embeddings).

Decoder: causal self-attention + cross-attention over the encoder memory
+ gated FFN, with a self-attn KV cache for decode and a *cross-KV cache*
computed once from the memory (the per-step cross K/V projections would
otherwise dominate decode FLOPs — this is the enc-dec analogue of the
paper keeping the shift registers out of the approximated datapath).

Both stacks are scanned over stacked per-layer parameters, like
``models.transformer``.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import DP, TP, constrain
from repro.models import attention, layers
from repro.models.attention import KVCache
from repro.models.layers import Ctx

__all__ = [
    "DecCache",
    "init_params",
    "encode",
    "decode_forward",
    "init_dec_caches",
    "precompute_cross",
]


class DecCache(NamedTuple):
    self_kv: KVCache  # (B, S_max, KV, hd) causal self-attn cache
    cross_k: jax.Array  # (B, S_mem, KV, hd) fixed after precompute
    cross_v: jax.Array


# ----------------------------------------------------------------- params
def _init_enc_block(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attention.init_attn(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": layers.init_mlp(k2, cfg, dtype),
    }


def _init_dec_block(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attention.init_attn(k1, cfg, dtype),
        "ln_cross": jnp.zeros((cfg.d_model,), dtype),
        "cross": {
            "cross_wq": layers.init_dense(k2, cfg.d_model, cfg.num_heads * cfg.head_dim, dtype),
            "cross_wk": layers.init_dense(
                jax.random.fold_in(k2, 1), cfg.d_model, cfg.num_kv_heads * cfg.head_dim, dtype
            ),
            "cross_wv": layers.init_dense(
                jax.random.fold_in(k2, 2), cfg.d_model, cfg.num_kv_heads * cfg.head_dim, dtype
            ),
            "cross_wo": layers.init_dense(
                jax.random.fold_in(k2, 3), cfg.num_heads * cfg.head_dim, cfg.d_model, dtype
            ),
        },
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "ffn": layers.init_mlp(k3, cfg, dtype),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ke, kh, kenc, kdec = jax.random.split(key, 4)
    params: dict = {
        "embed": (
            jax.random.normal(ke, (cfg.vocab_size, cfg.d_model), jnp.float32)
            * cfg.d_model**-0.5
        ).astype(dtype),
        "enc_final_norm": jnp.zeros((cfg.d_model,), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.init_dense(kh, cfg.d_model, cfg.vocab_size, dtype)
    enc_keys = jax.random.split(kenc, cfg.encoder_layers)
    dec_keys = jax.random.split(kdec, cfg.num_layers)
    params["enc_scan"] = jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(enc_keys)
    params["dec_scan"] = jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dec_keys)
    return params


# ------------------------------------------------------------------ remat
def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    policy = (
        jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        if cfg.remat == "dots"
        else jax.checkpoint_policies.nothing_saveable
    )
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------- encoder
def encode(params: dict, src_embeds: jax.Array, src_pos: jax.Array, ctx: Ctx) -> jax.Array:
    """src_embeds: (B, S_src, D) frame embeddings -> memory (B, S_src, D)."""
    cfg = ctx.cfg
    x = src_embeds.astype(jnp.dtype(cfg.dtype))
    x = constrain(x, DP, None, None)

    def body(x, p):
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        out, _ = attention.attention(p["attn"], h, src_pos, ctx, causal=False)
        x = x + out
        h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + layers.mlp(p["ffn"], h2, ctx)
        return constrain(x, DP, None, None), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["enc_scan"])
    return layers.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


# ------------------------------------------------------------- cross attn
def _cross_attend(p: dict, x: jax.Array, mem_pos: jax.Array,
                  ck: jax.Array, cv: jax.Array, ctx: Ctx) -> jax.Array:
    """Cross-attention against precomputed cross K/V (B, S_mem, KV, hd)."""
    cfg = ctx.cfg
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = layers.dense(x, p["cross_wq"], ctx, "attn").reshape(b, s, h, hd)
    q = constrain(q, DP, None, TP, None)
    k, v = ck, cv
    if h // kvh > 1:
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
    q_pos = jnp.zeros((b, s), jnp.int32)
    out = attention._attend_direct(
        q, k, v, q_pos, mem_pos, causal=False, window=None,
        softcap=None, scale=hd**-0.5,
    )
    out = out.reshape(b, s, h * hd).astype(x.dtype)
    out = constrain(out, DP, None, TP)
    return layers.dense(out, p["cross_wo"], ctx, "attn")


def precompute_cross(params: dict, memory: jax.Array, ctx: Ctx) -> tuple[jax.Array, jax.Array]:
    """Stacked (L, B, S_mem, KV, hd) cross K/V from the encoder memory."""
    cfg = ctx.cfg
    b, sm, _ = memory.shape
    kvh, hd = cfg.num_kv_heads, cfg.head_dim

    def one(p):
        ck = layers.dense(memory, p["cross"]["cross_wk"], ctx, "attn").reshape(b, sm, kvh, hd)
        cv = layers.dense(memory, p["cross"]["cross_wv"], ctx, "attn").reshape(b, sm, kvh, hd)
        return ck, cv

    return jax.lax.map(one, params["dec_scan"])


# ---------------------------------------------------------------- decoder
def init_dec_caches(cfg: ModelConfig, batch: int, max_seq: int, mem_len: int, dtype) -> DecCache:
    """Stacked (L, ...) decoder caches (self KV + cross KV slots)."""
    kv = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    xkv = (batch, mem_len, cfg.num_kv_heads, cfg.head_dim)
    L = cfg.num_layers

    def stack(shape):
        return jnp.zeros((L,) + shape, dtype)

    return DecCache(
        self_kv=KVCache(stack(kv), stack(kv)),
        cross_k=stack(xkv),
        cross_v=stack(xkv),
    )


def decode_forward(
    params: dict,
    tokens: jax.Array,
    positions: jax.Array,
    mem_pos: jax.Array,
    ctx: Ctx,
    *,
    memory: Optional[jax.Array] = None,
    caches: Optional[DecCache] = None,
    cache_pos=None,
) -> tuple[jax.Array, Optional[DecCache]]:
    """Decoder forward.  Either ``memory`` (training/prefill: cross K/V are
    computed on the fly) or ``caches`` with precomputed cross K/V must be
    given.  Returns (hidden, new_caches)."""
    cfg = ctx.cfg
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    x = constrain(x, DP, None, None)
    b, s, _ = x.shape
    kvh, hd = cfg.num_kv_heads, cfg.head_dim

    def body(carry, xs):
        x = carry
        if caches is not None:
            p, skv, ck, cv = xs
        else:
            p = xs
            skv = ck = cv = None
        h = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        out, new_skv = attention.attention(
            p["attn"], h, positions, ctx, cache=skv, cache_pos=cache_pos
        )
        x = x + out
        hc = layers.rms_norm(x, p["ln_cross"], cfg.norm_eps)
        if caches is not None:
            x = x + _cross_attend(p["cross"], hc, mem_pos, ck, cv, ctx)
        else:
            mk = layers.dense(memory, p["cross"]["cross_wk"], ctx, "attn").reshape(
                b, memory.shape[1], kvh, hd
            )
            mv = layers.dense(memory, p["cross"]["cross_wv"], ctx, "attn").reshape(
                b, memory.shape[1], kvh, hd
            )
            x = x + _cross_attend(p["cross"], hc, mem_pos, mk, mv, ctx)
        h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + layers.mlp(p["ffn"], h2, ctx)
        x = constrain(x, DP, None, None)
        return x, new_skv

    if caches is not None:
        x, new_skv = jax.lax.scan(
            _remat(body, cfg), x,
            (params["dec_scan"], caches.self_kv, caches.cross_k, caches.cross_v),
        )
        new_caches = DecCache(new_skv, caches.cross_k, caches.cross_v)
    else:
        x, _ = jax.lax.scan(_remat(body, cfg), x, params["dec_scan"])
        new_caches = None

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_caches
