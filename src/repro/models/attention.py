"""Multi-head attention: GQA/MQA, RoPE/M-RoPE, qk-norm, logit softcaps,
sliding-window (local) masking, and a KV cache for prefill + decode.

Tensor-parallel layout: attention runs on a *flat* head axis H = KV * G
(k/v are repeated from KV to H at use — the cache stays unrepeated), so a
single ``model``-axis constraint shards the whole computation whenever H
divides the axis (true for 8/10 assigned archs at model=16; qwen2-vl H=28
and recurrentgemma H=10 replicate and are flagged in EXPERIMENTS.md).

Prefill / training uses a blockwise online-softmax (flash-style)
formulation: an outer ``lax.map`` over query chunks and an inner
``lax.scan`` over key chunks carrying (running max, denominator,
accumulator) — peak live logits are (B, H, q_chunk, k_chunk) instead of
(B, H, S, T).

Decode (s == 1) takes the direct path with the KV cache *sequence* axis
sharded over the model axis (flash-decode style): per-device partial
logits over T/|model| keys, with the softmax max/sum reductions lowering
to all-reduces — this is what makes decode_32k × batch 128 fit.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import DP, TP, ambient_mesh, constrain
from repro.models import layers
from repro.models.layers import Ctx

__all__ = ["KVCache", "init_attn", "attention", "init_kv_cache"]

NEG_INF = -2.3819763e38  # bf16-safe large negative
Q_CHUNK = 1024
K_CHUNK = 1024


def _no_mesh() -> bool:
    return ambient_mesh() is None


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, KV, hd)
    v: jax.Array


def _row_update(cache: jax.Array, update: jax.Array, starts: jax.Array) -> jax.Array:
    """Per-row dynamic_update_slice along the cache sequence axis.

    cache (B, T, KV, hd), update (B, S, KV, hd), starts (B,) int32: row i's
    update lands at sequence offset starts[i].  Lowered as a batched
    scatter, this is what lets continuous-batching slots sit at different
    depths of the same physical cache.
    """
    def one(c, u, p):
        return jax.lax.dynamic_update_slice(c, u, (p, 0, 0))

    return jax.vmap(one)(cache, update, starts)


def init_attn(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": layers.init_dense(kq, cfg.d_model, cfg.num_heads * cfg.head_dim, dtype),
        "wk": layers.init_dense(kk, cfg.d_model, cfg.num_kv_heads * cfg.head_dim, dtype),
        "wv": layers.init_dense(kv, cfg.d_model, cfg.num_kv_heads * cfg.head_dim, dtype),
        "wo": layers.init_dense(ko, cfg.num_heads * cfg.head_dim, cfg.d_model, dtype),
    }
    if cfg.use_qk_norm and not cross:
        p["q_norm_scale"] = jnp.zeros((cfg.head_dim,), dtype)
        p["k_norm_scale"] = jnp.zeros((cfg.head_dim,), dtype)
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> KVCache:
    shape = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def _apply_rope(x, positions, ctx: Ctx):
    cfg = ctx.cfg
    if cfg.use_mrope:
        return layers.mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return layers.rope(x, positions, cfg.rope_theta)


def _allow(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """(B, Sq, Sk) boolean allow-mask from position ids."""
    m = k_pos[:, None, :] >= 0  # -1 marks unwritten cache slots
    if causal:
        m &= q_pos[:, :, None] >= k_pos[:, None, :]
    if window is not None:
        m &= q_pos[:, :, None] - k_pos[:, None, :] < window
    return m


def _scores(q, k, softcap, scale):
    # q: (B, Sq, H, hd), k: (B, Sk, H, hd) -> (B, H, Sq, Sk)
    s = jnp.einsum("bqhd,bthd->bhqt", q.astype(jnp.float32), k.astype(jnp.float32))
    s *= scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    return s


def _attend_direct(q, k, v, q_pos, k_pos, *, causal, window, softcap, scale):
    logits = _scores(q, k, softcap, scale)
    allow = _allow(q_pos, k_pos, causal=causal, window=window)
    logits = jnp.where(allow[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqt,bthd->bqhd", probs, v.astype(jnp.float32))


def _attend_flash(q, k, v, q_pos, k_pos, *, causal, window, softcap, scale,
                  q_chunk=Q_CHUNK, k_chunk=K_CHUNK):
    """Blockwise attention; q (B,S,H,hd), k/v (B,T,H,hd)."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    q_chunk = min(q_chunk, s)
    k_chunk = min(k_chunk, t)
    assert s % q_chunk == 0 and t % k_chunk == 0, (s, t, q_chunk, k_chunk)
    nq, nk = s // q_chunk, t // k_chunk

    kc = jnp.moveaxis(k.reshape(b, nk, k_chunk, h, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nk, k_chunk, h, hd), 1, 0)
    kpc = jnp.moveaxis(k_pos.reshape(b, nk, k_chunk), 1, 0)

    def q_block(args):
        qb, qpb = args  # (B, qc, H, hd), (B, qc)

        def k_step(carry, xs):
            m, l, acc = carry
            kb, vb, kpb = xs
            logits = _scores(qb, kb, softcap, scale)  # (B,H,qc,kc)
            allow = _allow(qpb, kpb, causal=causal, window=window)
            logits = jnp.where(allow[:, None, :, :], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqt,bthd->bhqd", p, vb.astype(jnp.float32)
            )
            return (m_new, l, acc), None

        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), (kc, vc, kpc))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,H,qc,hd)
        return jnp.moveaxis(out, 1, 2)  # (B,qc,H,hd)

    qb = jnp.moveaxis(q.reshape(b, nq, q_chunk, h, hd), 1, 0)
    qpb = jnp.moveaxis(q_pos.reshape(b, nq, q_chunk), 1, 0)
    out = jax.lax.map(q_block, (qb, qpb))  # (nq, B, qc, H, hd)
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)


def attention(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    ctx: Ctx,
    *,
    local: bool = False,
    causal: bool = True,
    cache: Optional[KVCache] = None,
    cache_pos: Optional[jax.Array] = None,
    kv_x: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
) -> tuple[jax.Array, Optional[KVCache]]:
    """General attention.

    Self-attention: ``kv_x`` is None.  Cross-attention: ``kv_x`` is the
    encoder memory (not causal, no rope).  Decode: ``cache`` given,
    x is (B, 1, D) and ``cache_pos`` the int32 cache write offset —
    either a scalar (legacy: physical slot == position for every row) or
    a per-row ``(B,)`` vector.  With a vector, ``positions`` carries each
    row's *true* position ids and the per-slot key positions are derived
    from the row's pad offset ``cache_pos + S - 1 - positions[:, -1]``:
    slot j of row i holds true position ``j - offset_i`` and slots outside
    ``[offset_i, cache_pos_i + S - 1]`` (left pads, unwritten tail, the
    admission hole of a retired-and-refilled slot) are masked invalid.
    This is what lets left-padded prompts decode at their true positions
    and lets the continuous-batching scheduler keep rows at different
    depths of one physical cache.
    """
    cfg = ctx.cfg
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    mpos = positions if not cfg.use_mrope else positions[0]  # masks use t-ids

    q = layers.dense(x, params["wq"], ctx, "attn").reshape(b, s, h, hd)
    src = x if kv_x is None else kv_x
    k = layers.dense(src, params["wk"], ctx, "attn").reshape(b, src.shape[1], kvh, hd)
    v = layers.dense(src, params["wv"], ctx, "attn").reshape(b, src.shape[1], kvh, hd)

    if cfg.use_qk_norm and "q_norm_scale" in params:
        q = layers.rms_norm(q, params["q_norm_scale"], cfg.norm_eps)
        k = layers.rms_norm(k, params["k_norm_scale"], cfg.norm_eps)
    if kv_x is None:
        q = _apply_rope(q, positions, ctx)
        k = _apply_rope(k, positions if kv_positions is None else kv_positions, ctx)
    q = constrain(q, DP, None, TP, None)

    decode = s == 1 and cache is not None
    per_row = cache_pos is not None and getattr(cache_pos, "ndim", 0) >= 1
    if cache is not None and kv_x is None:
        if per_row:
            starts = jnp.asarray(cache_pos, jnp.int32)
            kfull = _row_update(cache.k, k.astype(cache.k.dtype), starts)
            vfull = _row_update(cache.v, v.astype(cache.v.dtype), starts)
        else:
            kfull = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, cache_pos, 0, 0)
            )
            vfull = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, cache_pos, 0, 0)
            )
        if decode:  # flash-decode: shard the cache sequence axis over TP
            kfull = constrain(kfull, DP, TP, None, None)
            vfull = constrain(vfull, DP, TP, None, None)
        new_cache = KVCache(kfull, vfull)
        k, v = kfull, vfull
        t = kfull.shape[1]
        jj = jnp.arange(t, dtype=jnp.int32)[None, :] * jnp.ones((b, 1), jnp.int32)
        if per_row:
            last = starts + jnp.int32(s - 1)  # (B,) physical slot of newest token
            offset = last - mpos[:, -1]  # physical - true == per-row left-pad
            k_pos = jnp.where(
                (jj >= offset[:, None]) & (jj <= last[:, None]),
                jj - offset[:, None],
                -1,
            )
        else:
            k_pos = jnp.where(jj <= cache_pos + s - 1, jj, -1)
        q_pos = mpos
    else:
        new_cache = None
        k_pos = mpos if kv_positions is None else kv_positions
        q_pos = mpos

    causal_ = causal and kv_x is None
    window = cfg.local_window if local else None
    scale = hd**-0.5
    softcap = cfg.attn_logit_softcap

    ap_attn = cfg.approx.for_target("attn") if (
        cfg.approx.enabled and "attn" in cfg.approx.targets
    ) else None
    fused_approx = (
        ap_attn is not None
        and ap_attn.mode in ("bitexact", "lowrank")
        and ap_attn.backend != "reference"
        and cfg.attn_impl == "pallas"
        and not decode
    )

    if not decode and cfg.attn_impl == "pallas":
        # VMEM-resident flash kernel; k/v stay unrepeated (GQA head
        # mapping happens in the BlockSpec index_map, not in HBM)
        from repro.kernels.flash_attention import flash_attention
        from repro.kernels.ops import use_interpret

        k = constrain(k, DP, None, None, None)
        v = constrain(v, DP, None, None, None)

        def _block(dim: int) -> int:  # largest power-of-two divisor <= 512
            b_ = 512
            while b_ > 1 and dim % b_:
                b_ //= 2
            return b_

        if fused_approx:
            # quality-tier attention: the QK/AV contractions themselves
            # run through the approximate multiplier inside the online-
            # softmax tile loop (kernels/approx_attention.py) — the
            # projections above already went through the engine.
            from repro.kernels.approx_attention import (
                approx_flash_attention, attn_tiles, validate_attn_mode,
            )

            validate_attn_mode(ap_attn.mode, ap_attn.n)
            bq_d, bk_d = attn_tiles(ap_attn.mode)
            out = approx_flash_attention(
                q, k, v, q_pos, k_pos, ap_attn.mode, ap_attn.n, ap_attn.t,
                ap_attn.fix_to_1, ap_attn.rank, causal_, window, softcap,
                scale, min(_block(q.shape[1]), bq_d),
                min(_block(k.shape[1]), bk_d), use_interpret(),
            )
        else:
            out = flash_attention(
                q, k, v, q_pos, k_pos, causal_, window, softcap, scale,
                _block(q.shape[1]), _block(k.shape[1]), use_interpret(),
            )
    elif decode and cfg.attn_impl == "pallas" and _no_mesh():
        # single-device serving: stream the KV cache through VMEM
        # (multi-device decode keeps the XLA path — the cache is
        # sequence-sharded over the model axis there)
        from repro.kernels.flash_attention import flash_decode
        from repro.kernels.ops import use_interpret

        out = flash_decode(
            q[:, 0], k, v, mpos[:, -1], k_pos,
            window=window, softcap=softcap, scale=scale,
            interpret=use_interpret(),
        )[:, None]
    else:
        # GQA: repeat kv to the flat head axis (cache stays unrepeated)
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        if not decode:
            k = constrain(k, DP, None, TP, None)
            v = constrain(v, DP, None, TP, None)
        if not decode and (s > Q_CHUNK or k.shape[1] > 4 * K_CHUNK):
            out = _attend_flash(
                q, k, v, q_pos, k_pos, causal=causal_, window=window, softcap=softcap, scale=scale
            )
        else:
            out = _attend_direct(
                q, k, v, q_pos, k_pos, causal=causal_, window=window, softcap=softcap, scale=scale
            )
    out = out.reshape(b, s, h * hd).astype(x.dtype)
    out = constrain(out, DP, None, TP)
    return layers.dense(out, params["wo"], ctx, "attn"), new_cache
