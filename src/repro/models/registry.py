"""Model registry: one uniform handle over decoder-only and enc-dec stacks.

``build_model(cfg)`` returns a ``Model`` whose methods close over the
config and dispatch by family.  All higher layers (train steps, serving,
dry-run) go through this interface only.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, transformer
from repro.models.layers import Ctx

__all__ = ["Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- params
    def init_params(self, key: jax.Array) -> dict:
        if self.cfg.is_encdec:
            return encdec.init_params(key, self.cfg)
        return transformer.init_params(key, self.cfg)

    def ctx(self, rng: Optional[jax.Array] = None) -> Ctx:
        return Ctx(cfg=self.cfg, rng=rng)

    # ------------------------------------------------------------ forward
    def forward(
        self,
        params: dict,
        tokens: Optional[jax.Array],
        positions: jax.Array,
        ctx: Ctx,
        *,
        embeds: Optional[jax.Array] = None,
        src_embeds: Optional[jax.Array] = None,
        src_pos: Optional[jax.Array] = None,
        caches: Any = None,
        cache_pos=None,
    ) -> tuple[jax.Array, Any, jax.Array]:
        """Returns (hidden (B, S, D), new_caches, aux_loss)."""
        if self.cfg.is_encdec:
            if caches is None:
                memory = encdec.encode(params, src_embeds, src_pos, ctx)
                hidden, _ = encdec.decode_forward(
                    params, tokens, positions, src_pos, ctx, memory=memory
                )
                return hidden, None, jnp.float32(0.0)
            mem_len = caches.cross_k.shape[2]
            mem_pos = jnp.arange(mem_len, dtype=jnp.int32)[None, :] * jnp.ones(
                (tokens.shape[0], 1), jnp.int32
            )
            hidden, new_caches = encdec.decode_forward(
                params, tokens, positions, mem_pos, ctx,
                caches=caches, cache_pos=cache_pos,
            )
            return hidden, new_caches, jnp.float32(0.0)
        return transformer.forward(
            params, tokens, positions, ctx,
            embeds=embeds, caches=caches, cache_pos=cache_pos,
        )

    def lm_head(self, params: dict, hidden: jax.Array) -> jax.Array:
        return transformer.lm_head(params, hidden, self.cfg)

    # ------------------------------------------------------------- caches
    def init_caches(self, batch: int, max_seq: int, dtype, *, mem_len: int = 0):
        if self.cfg.is_encdec:
            return encdec.init_dec_caches(self.cfg, batch, max_seq, mem_len, dtype)
        return transformer.init_caches(self.cfg, batch, max_seq, dtype)

    # ------------------------------------------------- enc-dec extras
    def encode(self, params, src_embeds, src_pos, ctx):
        assert self.cfg.is_encdec
        return encdec.encode(params, src_embeds, src_pos, ctx)

    def precompute_cross(self, params, memory, ctx):
        assert self.cfg.is_encdec
        return encdec.precompute_cross(params, memory, ctx)

    def param_count(self, params: dict) -> int:
        return sum(p.size for p in jax.tree_util.tree_leaves(params))


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg)
