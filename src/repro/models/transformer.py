"""Decoder-only transformer stack: scanned layer groups, mixed block kinds.

The layer pattern (e.g. gemma2's ("attn_local", "attn_global"), griffin's
("rglru", "rglru", "attn_local")) defines a *group*; ``num_layers //
len(pattern)`` groups are evaluated under one ``jax.lax.scan`` over
stacked parameters (compile time and HLO size stay O(group), not
O(depth)), with any remainder layers unrolled.  Remat (configurable
policy) wraps the group body.

Caches (KV / RG-LRU / SSD states) are pytrees stacked the same way and
threaded through the scan as (xs -> ys).

The forward pass returns final *hidden states*; logits are produced by
``lm_head()`` (or, in training, never fully materialized — the loss is
computed in vocab-chunked form, see train/steps.py).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import DP, TP, constrain
from repro.models import attention, layers, moe, rglru, ssd
from repro.models.layers import Ctx

__all__ = [
    "init_params",
    "init_caches",
    "forward",
    "lm_head",
    "block_kinds",
]


def block_kinds(cfg: ModelConfig) -> list[str]:
    pat = cfg.layer_pattern
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


def _has_ffn(cfg: ModelConfig, kind: str) -> bool:
    if kind == "ssd":
        return cfg.d_ff > 0
    return cfg.d_ff > 0 or cfg.num_experts > 0


def _init_block(key, cfg: ModelConfig, kind: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if kind in ("attn_global", "attn_local"):
        p["attn"] = attention.init_attn(k1, cfg, dtype)
    elif kind == "rglru":
        p["rglru"] = rglru.init_rglru(k1, cfg, dtype)
    elif kind == "ssd":
        p["ssd"] = ssd.init_ssd(k1, cfg, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if cfg.use_post_norm:
        p["post_ln1"] = jnp.zeros((cfg.d_model,), dtype)
    if _has_ffn(cfg, kind):
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        if cfg.num_experts > 0:
            p["ffn_moe"] = moe.init_moe(k2, cfg, dtype)
        else:
            p["ffn"] = layers.init_mlp(k2, cfg, dtype)
        if cfg.use_post_norm:
            p["post_ln2"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def _apply_block(
    params: dict,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    ctx: Ctx,
    cache: Any,
    cache_pos,
) -> tuple[jax.Array, Any, jax.Array]:
    cfg = ctx.cfg
    h = layers.rms_norm(x, params["ln1"], cfg.norm_eps)
    if kind in ("attn_global", "attn_local"):
        out, new_cache = attention.attention(
            params["attn"], h, positions, ctx,
            local=(kind == "attn_local"), cache=cache, cache_pos=cache_pos,
        )
    elif kind == "rglru":
        out, new_cache = rglru.rglru_block(params["rglru"], h, ctx, cache=cache)
    elif kind == "ssd":
        out, new_cache = ssd.ssd_block(params["ssd"], h, ctx, cache=cache)
    else:
        raise ValueError(kind)
    if cfg.use_post_norm:
        out = layers.rms_norm(out, params["post_ln1"], cfg.norm_eps)
    x = x + out
    aux = jnp.float32(0.0)
    if _has_ffn(cfg, kind):
        h2 = layers.rms_norm(x, params["ln2"], cfg.norm_eps)
        if cfg.num_experts > 0:
            out2, aux = moe.moe_ffn(params["ffn_moe"], h2, ctx)
        else:
            out2 = layers.mlp(params["ffn"], h2, ctx)
        if cfg.use_post_norm:
            out2 = layers.rms_norm(out2, params["post_ln2"], cfg.norm_eps)
        x = x + out2
    if cfg.seq_shard_residuals:
        x = constrain(x, DP, TP, None)  # sequence-parallel residual stream
    else:
        x = constrain(x, DP, None, None)
    return x, new_cache, aux


def _init_cache_for(cfg: ModelConfig, kind: str, batch: int, max_seq: int, dtype):
    if kind in ("attn_global", "attn_local"):
        return attention.init_kv_cache(cfg, batch, max_seq, dtype)
    if kind == "rglru":
        return rglru.init_rglru_cache(cfg, batch, dtype)
    if kind == "ssd":
        return ssd.init_ssd_cache(cfg, batch, dtype)
    raise ValueError(kind)


# --------------------------------------------------------------------- init
def init_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    kinds = block_kinds(cfg)
    period = len(cfg.layer_pattern)
    repeats = cfg.num_layers // period if cfg.scan_layers else 0
    rem_kinds = kinds[repeats * period :]

    ke, kh, kb = jax.random.split(key, 3)
    params: dict = {
        "embed": (
            jax.random.normal(ke, (cfg.vocab_size, cfg.d_model), jnp.float32)
            * cfg.d_model**-0.5
        ).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.init_dense(kh, cfg.d_model, cfg.vocab_size, dtype)

    if repeats:
        def init_group(gkey):
            sub = jax.random.split(gkey, period)
            return {f"sub{i}": _init_block(sub[i], cfg, cfg.layer_pattern[i], dtype)
                    for i in range(period)}

        gkeys = jax.random.split(kb, repeats + 1)
        stacked = jax.vmap(init_group)(gkeys[:repeats])
        params["scan"] = stacked
        rem_key = gkeys[repeats]
    else:
        rem_key = kb
    if rem_kinds:
        rkeys = jax.random.split(rem_key, len(rem_kinds))
        params["rem"] = [
            _init_block(rkeys[i], cfg, kind, dtype) for i, kind in enumerate(rem_kinds)
        ]
    return params


def init_caches(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    kinds = block_kinds(cfg)
    period = len(cfg.layer_pattern)
    repeats = cfg.num_layers // period if cfg.scan_layers else 0
    rem_kinds = kinds[repeats * period :]
    caches: dict = {}
    if repeats:
        def one_group(_):
            return {
                f"sub{i}": _init_cache_for(cfg, cfg.layer_pattern[i], batch, max_seq, dtype)
                for i in range(period)
            }

        caches["scan"] = jax.vmap(one_group)(jnp.arange(repeats))
    if rem_kinds:
        caches["rem"] = [
            _init_cache_for(cfg, kind, batch, max_seq, dtype) for kind in rem_kinds
        ]
    return caches


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ------------------------------------------------------------------ forward
def forward(
    params: dict,
    tokens: Optional[jax.Array],
    positions: jax.Array,
    ctx: Ctx,
    *,
    embeds: Optional[jax.Array] = None,
    caches: Optional[dict] = None,
    cache_pos=None,
) -> tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (hidden (B, S, D), new_caches, aux_loss)."""
    cfg = ctx.cfg
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    x = constrain(x, DP, TP if cfg.seq_shard_residuals else None, None)

    period = len(cfg.layer_pattern)
    repeats = cfg.num_layers // period if cfg.scan_layers else 0
    new_caches: dict = {}

    if repeats:
        def group_body(carry, xs):
            x, aux = carry
            gparams, gcache = xs
            for i in range(period):
                kind = cfg.layer_pattern[i]
                sub_cache = gcache[f"sub{i}"] if gcache is not None else None
                x, nc, a = _apply_block(
                    gparams[f"sub{i}"], kind, x, positions, ctx, sub_cache, cache_pos
                )
                if gcache is not None:
                    gcache = dict(gcache)
                    gcache[f"sub{i}"] = nc
                aux = aux + a
            return (x, aux), gcache

        body = _remat(group_body, cfg)
        scan_caches = caches.get("scan") if caches else None
        if scan_caches is None:
            # keep xs pytree structure static: pass params only
            (x, aux), _ = jax.lax.scan(
                lambda c, p: (body(c, (p, None))[0], None),
                (x, jnp.float32(0.0)),
                params["scan"],
            )
        else:
            (x, aux), new_scan = jax.lax.scan(
                body, (x, jnp.float32(0.0)), (params["scan"], scan_caches)
            )
            new_caches["scan"] = new_scan
    else:
        aux = jnp.float32(0.0)

    kinds = block_kinds(cfg)
    rem_kinds = kinds[repeats * period :]
    for i, kind in enumerate(rem_kinds):
        rcache = caches["rem"][i] if caches and "rem" in caches else None
        x, nc, a = _apply_block(
            params["rem"][i], kind, x, positions, ctx, rcache, cache_pos
        )
        aux = aux + a
        if rcache is not None:
            new_caches.setdefault("rem", [None] * len(rem_kinds))[i] = nc

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, (new_caches if caches else None), aux


def lm_head(params: dict, hidden: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full logits (B, S, V).  Use only for small S (decode / smoke tests)."""
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", hidden.astype(jnp.float32), w.astype(jnp.float32))
    if cfg.final_logit_softcap:
        logits = jnp.tanh(logits / cfg.final_logit_softcap) * cfg.final_logit_softcap
    logits = constrain(logits, DP, None, TP)
    return logits
