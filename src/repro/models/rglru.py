"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block:  x -> [in_x proj -> causal conv1d -> RG-LRU]  *  gelu(in_gate proj)
          -> out proj

RG-LRU recurrence (De et al., 2024):
    r_t = sigmoid(x_t W_r + b_r)              recurrence gate
    i_t = sigmoid(x_t W_i + b_i)              input gate
    a_t = exp(-c * softplus(Lambda) * r_t)    per-channel decay, c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluates the linear recurrence with a log-depth
``jax.lax.associative_scan`` (this is what makes long_500k prefill
feasible); decode is the O(1) single-step update on a carried state.

The in/gate/out projections route through the approximate multiplier; the
recurrence itself stays exact — it is the *accumulator*, the analogue of
the paper's shift registers, which the paper never approximates.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import DP, TP, constrain
from repro.models import layers
from repro.models.layers import Ctx

__all__ = ["RGLRUCache", "init_rglru", "rglru_block", "init_rglru_cache"]

_C = 8.0


class RGLRUCache(NamedTuple):
    conv: jax.Array  # (B, conv_width - 1, W) trailing inputs
    h: jax.Array  # (B, W) recurrent state


def init_rglru(key, cfg: ModelConfig, dtype) -> dict:
    w = cfg.lru_width
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    lam = jnp.log(jnp.expm1(jnp.linspace(0.9, 0.999, w)))  # softplus^-1 of decays
    return {
        "in_x": layers.init_dense(ks[0], d, w, dtype),
        "in_gate": layers.init_dense(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "lru_a": lam.astype(jnp.float32),  # Lambda (softplus -> decay rate)
        "lru_gate_w": (jax.random.normal(ks[3], (w, w), jnp.float32) * w**-0.5).astype(dtype),
        "lru_gate_b": jnp.zeros((w,), dtype),
        "lru_in_w": (jax.random.normal(ks[4], (w, w), jnp.float32) * w**-0.5).astype(dtype),
        "lru_in_b": jnp.zeros((w,), dtype),
        "out_proj": layers.init_dense(ks[5], w, d, dtype),
    }


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> RGLRUCache:
    return RGLRUCache(
        conv=jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), dtype),
        h=jnp.zeros((batch, cfg.lru_width), jnp.float32),
    )


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 cache: Optional[jax.Array]) -> tuple[jax.Array, Optional[jax.Array]]:
    """Depthwise causal conv1d.  x: (B, S, W); w: (K, W)."""
    k = w.shape[0]
    if cache is not None:
        ctx_in = jnp.concatenate([cache.astype(x.dtype), x], axis=1)  # (B, K-1+S, W)
        new_cache = ctx_in[:, -(k - 1):, :] if k > 1 else cache
    else:
        ctx_in = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_cache = None
    out = jnp.zeros_like(x, shape=x.shape)
    s = x.shape[1]
    out = sum(
        ctx_in[:, i : i + s, :] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :], new_cache


def _rglru_scan(xb: jax.Array, a_t: jax.Array, i_t: jax.Array,
                h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.

    xb, a_t, i_t: (B, S, W) f32.  Returns (h over S, final h).
    """
    b_t = jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 0.0)) * (i_t * xb)
    # fold the initial state into the first element
    b_t = b_t.at[:, 0, :].add(a_t[:, 0, :] * h0)

    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_all, h_all = jax.lax.associative_scan(comb, (a_t, b_t), axis=1)
    return h_all, h_all[:, -1, :]


def rglru_block(
    params: dict,
    x: jax.Array,
    ctx: Ctx,
    cache: Optional[RGLRUCache] = None,
) -> tuple[jax.Array, Optional[RGLRUCache]]:
    """x: (B, S, d_model) -> (out, new_cache)."""
    xb = layers.dense(x, params["in_x"], ctx, "mlp")  # (B, S, W)
    gb = layers.dense(x, params["in_gate"], ctx, "mlp")
    xb = constrain(xb, DP, None, TP)

    conv_cache = cache.conv if cache is not None else None
    xb, new_conv = _causal_conv(xb, params["conv_w"], params["conv_b"], conv_cache)

    xb32 = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(
        xb32 @ params["lru_gate_w"].astype(jnp.float32) + params["lru_gate_b"].astype(jnp.float32)
    )
    i = jax.nn.sigmoid(
        xb32 @ params["lru_in_w"].astype(jnp.float32) + params["lru_in_b"].astype(jnp.float32)
    )
    log_a = -_C * jax.nn.softplus(params["lru_a"]) * r  # (B, S, W)
    a_t = jnp.exp(log_a)

    if cache is not None and x.shape[1] == 1:
        # O(1) decode step
        a1, i1, x1 = a_t[:, 0], i[:, 0], xb32[:, 0]
        h = a1 * cache.h + jnp.sqrt(jnp.maximum(1.0 - a1 * a1, 0.0)) * (i1 * x1)
        h_seq = h[:, None, :]
    else:
        h0 = cache.h if cache is not None else jnp.zeros(
            (x.shape[0], ctx.cfg.lru_width), jnp.float32
        )
        h_seq, h = _rglru_scan(xb32, a_t, i, h0)

    out = h_seq.astype(x.dtype) * jax.nn.gelu(gb, approximate=True)
    out = constrain(out, DP, None, TP)
    out = layers.dense(out, params["out_proj"], ctx, "mlp")
    new_cache = RGLRUCache(new_conv, h) if cache is not None else None
    return out, new_cache
