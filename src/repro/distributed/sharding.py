"""Sharding rules: parameters, activations, caches.

Conventions (DESIGN.md §4):
  mesh axes   ("pod", "data", "model") multi-pod / ("data", "model") pod
  DP          batch over ("pod", "data")
  TP          heads / d_ff / vocab / experts over "model"
  FSDP        the largest remaining param dim over "data"

Every rule degrades gracefully: an axis is only assigned if the dimension
is divisible by the mesh extent (e.g. granite's vocab 49155 is not 16-
divisible -> falls back to the next candidate or replication).  Constraints
are no-ops outside a mesh context, so the same model code runs on one CPU
device and on the 512-chip production mesh.
"""

from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

DP = ("pod", "data")  # logical data-parallel axes (present subset is used)
TP = "model"
FSDP = "data"

__all__ = [
    "DP", "TP", "FSDP", "ambient_mesh", "mesh_context", "make_auto_mesh",
    "data_parallel_mesh", "shard_map", "constrain", "param_spec",
    "param_specs", "mesh_axis_sizes",
]


def ambient_mesh():
    """The mesh the current trace runs under, or None — across jax versions.

    Newer jax exposes ``jax.sharding.get_abstract_mesh`` (set by
    ``jax.sharding.set_mesh``/``use_mesh``); older releases (< 0.5) only
    have the thread-local physical mesh installed by ``with mesh:``.
    Every rule in this module degrades to a no-op when this returns None,
    so the same model code runs on one CPU device and on the production
    mesh regardless of the installed jax.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        try:
            m = get()
        except Exception:
            m = None
        if m is not None and not getattr(m, "empty", True):
            return m
    try:
        from jax._src import mesh as _mesh_lib

        pm = _mesh_lib.thread_resources.env.physical_mesh
    except Exception:
        return None
    if pm is None or pm.empty:
        return None
    return pm


def mesh_context(mesh):
    """Context manager installing ``mesh`` for the duration of a trace.

    ``jax.sharding.set_mesh`` where available, the legacy ``with mesh:``
    resource-env context otherwise.
    """
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_auto_mesh(shape: tuple, axes: tuple):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def data_parallel_mesh(batch_size: Optional[int] = None, *, devices=None):
    """A 1-D ``("data",)`` serving mesh over the available devices, or None.

    Picks the largest device count that divides ``batch_size`` (all of
    them when ``batch_size`` is None), so installing the result around a
    decode loop shards the request batch over data via the model's
    ambient ``constrain`` rules.  Returns None on a single device (or
    when nothing divides) — serving then runs unsharded, no mesh context
    needed.  This is the ``distributed`` half of the continuous-batching
    scheduler's optional data-parallel decode (docs/serving.md).
    """
    devs = list(devices if devices is not None else jax.devices())
    n = len(devs)
    if batch_size is not None:
        while n > 1 and batch_size % n:
            n -= 1
    if n <= 1:
        return None
    import numpy as np

    return jax.sharding.Mesh(np.array(devs[:n]), ("data",))


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions, replication checks off.

    Newer jax spells it ``jax.shard_map(..., check_vma=False)``; older
    releases have ``jax.experimental.shard_map.shard_map(...,
    check_rep=False)``.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
        except TypeError:  # intermediate releases: check_rep spelling on jax.shard_map
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _sm  # jax < 0.6

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def mesh_axis_sizes(mesh=None) -> dict:
    m = mesh or ambient_mesh()
    if m is None:
        return {}
    return dict(zip(m.axis_names, m.axis_sizes if hasattr(m, "axis_sizes") else m.shape.values()))


def _resolve_entry(entry, dim: int, sizes: dict) -> Optional[object]:
    """Keep only mesh-present axes; drop the entry unless dim divides."""
    if entry is None:
        return None
    axes = entry if isinstance(entry, tuple) else (entry,)
    axes = tuple(a for a in axes if a in sizes)
    if not axes:
        return None
    total = 1
    for a in axes:
        total *= sizes[a]
    if dim % total != 0:
        # try a shrinking prefix (e.g. ("pod","data") -> ("pod",))
        for k in range(len(axes) - 1, 0, -1):
            tot = 1
            for a in axes[:k]:
                tot *= sizes[a]
            if dim % tot == 0:
                return axes[:k] if k > 1 else axes[0]
        return None
    return axes if len(axes) > 1 else axes[0]


def resolve_spec(spec: tuple, shape: tuple, sizes: dict) -> P:
    assert len(spec) == len(shape), (spec, shape)
    return P(*[_resolve_entry(e, d, sizes) for e, d in zip(spec, shape)])


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint that adapts to (or skips without) the mesh."""
    m = ambient_mesh()
    if m is None:
        return x
    sizes = mesh_axis_sizes(m)
    resolved = resolve_spec(tuple(spec), x.shape, sizes)
    if isinstance(m, jax.sharding.Mesh):  # concrete mesh (legacy `with mesh:` path)
        return jax.lax.with_sharding_constraint(x, jax.sharding.NamedSharding(m, resolved))
    return jax.lax.with_sharding_constraint(x, resolved)


# ---------------------------------------------------------------------------
# Parameter sharding rules, by parameter-tree path (joined with '/').
# Trailing-dims spec; leading (scan-group) dims are padded with None.
# Order matters: first match wins.
_RULES: list[tuple[str, tuple]] = [
    (r"embed", (TP, FSDP)),  # (vocab, d_model)
    (r"lm_head", (FSDP, TP)),  # (d_model, vocab)
    (r"(wq|wk|wv)$", (FSDP, TP)),  # (d_model, heads*hd)
    (r"wo$", (TP, FSDP)),  # (heads*hd, d_model)
    (r"(w1|w3)$", (FSDP, TP)),  # (d_model, d_ff)
    (r"w2$", (TP, FSDP)),  # (d_ff, d_model)
    (r"router", (FSDP, None)),  # (d_model, experts)
    (r"(we1|we3)$", (TP, FSDP, None)),  # (experts, d_model, ff)
    (r"we2$", (TP, None, FSDP)),  # (experts, ff, d_model)
    (r"(in_proj|gate_proj|x_proj)$", (FSDP, TP)),
    (r"out_proj$", (TP, FSDP)),
    (r"conv_w$", (None, TP)),  # (conv_width, channels)
    (r"(lru_a|lru_gate_w|lru_gate_b|conv_b)", None),  # small recurrent params
    (r"(ssm_a|ssm_d|dt_bias)$", (None,)),  # (heads,)
    (r"(norm|scale|bias)", None),  # norms etc: replicate
    (r"(^|/)(ln|post_ln)\d*$", None),  # layer-norm scales: replicate
    (r"(cross_wq|cross_wk|cross_wv)$", (FSDP, TP)),
    (r"cross_wo$", (TP, FSDP)),
]


def param_spec(path: str, shape: tuple, sizes: dict, *, fsdp: bool = True) -> P:
    """``fsdp=False`` drops the ZeRO-3 data-axis sharding (params/opt are
    then replicated over data, TP-sharded over model) — the right choice
    when the optimizer state fits, since it removes the per-microbatch
    weight all-gathers (EXPERIMENTS.md §Perf iteration 5)."""
    def strip(entry):
        if not fsdp:
            if entry == FSDP:
                return None
            if isinstance(entry, tuple):
                entry = tuple(a for a in entry if a != FSDP) or None
        return entry

    for pat, spec in _RULES:
        if re.search(pat, path):
            if spec is None:
                return P()
            spec = tuple(spec[-len(shape):]) if len(spec) <= len(shape) else spec
            full = (None,) * (len(shape) - len(spec)) + tuple(spec)
            full = tuple(strip(e) for e in full)
            return resolve_spec(full, shape, sizes)
    if len(shape) < 2 or not fsdp:  # unmatched vectors/scalars: replicate
        return P()
    # default: FSDP on the largest divisible dim
    best, best_dim = None, 0
    for i, d in enumerate(shape):
        if d > best_dim and sizes.get(FSDP, 1) > 0 and d % max(sizes.get(FSDP, 1), 1) == 0:
            best, best_dim = i, d
    spec = [None] * len(shape)
    if best is not None and sizes.get(FSDP):
        spec[best] = FSDP
    return P(*spec)


def param_specs(params, mesh, *, fsdp: bool = True) -> object:
    """Pytree of PartitionSpec mirroring ``params`` (works on shape structs)."""
    sizes = mesh_axis_sizes(mesh)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def path_str(kp):
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)

    specs = {path_str(kp): param_spec(path_str(kp), v.shape, sizes, fsdp=fsdp) for kp, v in flat}
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = [specs[path_str(kp)] for kp, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, out)
