"""Sharding rules: parameters, activations, caches.

Conventions (DESIGN.md §4):
  mesh axes   ("pod", "data", "model") multi-pod / ("data", "model") pod
  DP          batch over ("pod", "data")
  TP          heads / d_ff / vocab / experts over "model"
  FSDP        the largest remaining param dim over "data"

Every rule degrades gracefully: an axis is only assigned if the dimension
is divisible by the mesh extent (e.g. granite's vocab 49155 is not 16-
divisible -> falls back to the next candidate or replication).  Constraints
are no-ops outside a mesh context, so the same model code runs on one CPU
device and on the 512-chip production mesh.
"""

from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

DP = ("pod", "data")  # logical data-parallel axes (present subset is used)
TP = "model"
FSDP = "data"

__all__ = ["DP", "TP", "FSDP", "constrain", "param_spec", "param_specs", "mesh_axis_sizes"]


def _abstract_mesh():
    m = jax.sharding.get_abstract_mesh()
    if m is None or m.empty:
        return None
    return m


def mesh_axis_sizes(mesh=None) -> dict:
    m = mesh or _abstract_mesh()
    if m is None:
        return {}
    return dict(zip(m.axis_names, m.axis_sizes if hasattr(m, "axis_sizes") else m.shape.values()))


def _resolve_entry(entry, dim: int, sizes: dict) -> Optional[object]:
    """Keep only mesh-present axes; drop the entry unless dim divides."""
    if entry is None:
        return None
    axes = entry if isinstance(entry, tuple) else (entry,)
    axes = tuple(a for a in axes if a in sizes)
    if not axes:
        return None
    total = 1
    for a in axes:
        total *= sizes[a]
    if dim % total != 0:
        # try a shrinking prefix (e.g. ("pod","data") -> ("pod",))
        for k in range(len(axes) - 1, 0, -1):
            tot = 1
            for a in axes[:k]:
                tot *= sizes[a]
            if dim % tot == 0:
                return axes[:k] if k > 1 else axes[0]
        return None
    return axes if len(axes) > 1 else axes[0]


def resolve_spec(spec: tuple, shape: tuple, sizes: dict) -> P:
    assert len(spec) == len(shape), (spec, shape)
    return P(*[_resolve_entry(e, d, sizes) for e, d in zip(spec, shape)])


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint that adapts to (or skips without) the mesh."""
    m = _abstract_mesh()
    if m is None:
        return x
    sizes = mesh_axis_sizes(m)
    return jax.lax.with_sharding_constraint(x, resolve_spec(tuple(spec), x.shape, sizes))


# ---------------------------------------------------------------------------
# Parameter sharding rules, by parameter-tree path (joined with '/').
# Trailing-dims spec; leading (scan-group) dims are padded with None.
# Order matters: first match wins.
_RULES: list[tuple[str, tuple]] = [
    (r"embed", (TP, FSDP)),  # (vocab, d_model)
    (r"lm_head", (FSDP, TP)),  # (d_model, vocab)
    (r"(wq|wk|wv)$", (FSDP, TP)),  # (d_model, heads*hd)
    (r"wo$", (TP, FSDP)),  # (heads*hd, d_model)
    (r"(w1|w3)$", (FSDP, TP)),  # (d_model, d_ff)
    (r"w2$", (TP, FSDP)),  # (d_ff, d_model)
    (r"router", (FSDP, None)),  # (d_model, experts)
    (r"(we1|we3)$", (TP, FSDP, None)),  # (experts, d_model, ff)
    (r"we2$", (TP, None, FSDP)),  # (experts, ff, d_model)
    (r"(in_proj|gate_proj|x_proj)$", (FSDP, TP)),
    (r"out_proj$", (TP, FSDP)),
    (r"conv_w$", (None, TP)),  # (conv_width, channels)
    (r"(lru_a|lru_gate_w|lru_gate_b|conv_b)", None),  # small recurrent params
    (r"(ssm_a|ssm_d|dt_bias)$", (None,)),  # (heads,)
    (r"(norm|scale|bias)", None),  # norms etc: replicate
    (r"(^|/)(ln|post_ln)\d*$", None),  # layer-norm scales: replicate
    (r"(cross_wq|cross_wk|cross_wv)$", (FSDP, TP)),
    (r"cross_wo$", (TP, FSDP)),
]


def param_spec(path: str, shape: tuple, sizes: dict, *, fsdp: bool = True) -> P:
    """``fsdp=False`` drops the ZeRO-3 data-axis sharding (params/opt are
    then replicated over data, TP-sharded over model) — the right choice
    when the optimizer state fits, since it removes the per-microbatch
    weight all-gathers (EXPERIMENTS.md §Perf iteration 5)."""
    def strip(entry):
        if not fsdp:
            if entry == FSDP:
                return None
            if isinstance(entry, tuple):
                entry = tuple(a for a in entry if a != FSDP) or None
        return entry

    for pat, spec in _RULES:
        if re.search(pat, path):
            if spec is None:
                return P()
            spec = tuple(spec[-len(shape):]) if len(spec) <= len(shape) else spec
            full = (None,) * (len(shape) - len(spec)) + tuple(spec)
            full = tuple(strip(e) for e in full)
            return resolve_spec(full, shape, sizes)
    if len(shape) < 2 or not fsdp:  # unmatched vectors/scalars: replicate
        return P()
    # default: FSDP on the largest divisible dim
    best, best_dim = None, 0
    for i, d in enumerate(shape):
        if d > best_dim and sizes.get(FSDP, 1) > 0 and d % max(sizes.get(FSDP, 1), 1) == 0:
            best, best_dim = i, d
    spec = [None] * len(shape)
    if best is not None and sizes.get(FSDP):
        spec[best] = FSDP
    return P(*spec)


def param_specs(params, mesh, *, fsdp: bool = True) -> object:
    """Pytree of PartitionSpec mirroring ``params`` (works on shape structs)."""
    sizes = mesh_axis_sizes(mesh)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def path_str(kp):
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)

    specs = {path_str(kp): param_spec(path_str(kp), v.shape, sizes, fsdp=fsdp) for kp, v in flat}
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = [specs[path_str(kp)] for kp, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, out)
